"""vClos placement: stage semantics, ILP, reservation invariants."""

import numpy as np
import pytest

from repro.core.placement import (PlacementFailure, candidate_sizes, commit,
                                  release, vclos_place, _factorizations)
from repro.core.routing import SourceRouting, contention
from repro.core.topology import CLUSTER512, ClusterSpec, FabricState
from repro.core.traffic import pairwise_alltoall, ring_allreduce
from repro.core.patterns import remap


def fresh():
    return FabricState(CLUSTER512)


def test_stage0_single_server():
    st = fresh()
    p = vclos_place(st, 0, 4)
    assert p.kind == "server"
    assert len({CLUSTER512.server_of_gpu(g) for g in p.gpus}) == 1


def test_stage0_best_fit_packs_partial_servers():
    st = fresh()
    p1 = vclos_place(st, 0, 4)
    commit(st, p1)
    p2 = vclos_place(st, 1, 4)
    commit(st, p2)
    # best-fit: second job lands in the half-empty server
    assert {CLUSTER512.server_of_gpu(g) for g in p1.gpus} == \
        {CLUSTER512.server_of_gpu(g) for g in p2.gpus}


def test_stage1_single_leaf_no_links():
    st = fresh()
    p = vclos_place(st, 0, 16)
    assert p.kind == "leaf"
    assert len({CLUSTER512.leaf_of_gpu(g) for g in p.gpus}) == 1
    assert p.vclos is None  # no spine ports consumed


def test_stage2_builds_virtual_clos():
    st = fresh()
    p = vclos_place(st, 0, 64)
    assert p.kind == "vclos"
    vc = p.vclos
    assert vc.num_leafs * vc.gpus_per_leaf == 64
    assert vc.num_spines == vc.gpus_per_leaf
    # every (leaf, spine) pair reserved exactly once
    assert all(c == 1 for c in vc.links.values())
    assert len(vc.links) == vc.num_leafs * vc.num_spines


def test_vclos_gpu_exclusivity_and_link_capacity():
    st = fresh()
    jobs = []
    jid = 0
    rng = np.random.default_rng(0)
    while True:
        n = int(rng.choice([8, 32, 64, 96]))
        p = vclos_place(st, jid, n)
        if isinstance(p, PlacementFailure):
            break
        commit(st, p)
        jobs.append(p)
        jid += 1
    owners = {}
    for p in jobs:
        for g in p.gpus:
            assert g not in owners, "GPU double-allocated"
            owners[g] = p.job_id
    cap = st.capacity()
    for (n, m), per_job in st.link_owner.items():
        assert sum(per_job.values()) <= cap[n][m], "link over-reserved"


def test_vclos_traffic_contention_free_inside():
    """A placed job's ring AND AlltoAll must be contention-free on its own
    reserved sub-topology using its source-routing maps."""
    st = fresh()
    # fragment the cluster a little first
    commit(st, vclos_place(st, 100, 32))
    p = vclos_place(st, 0, 64)
    commit(st, p)
    sr = SourceRouting(CLUSTER512)
    maps = dict(sr.maps)
    for leaf, rmap in p.routing_maps.items():
        merged = dict(maps[leaf])
        merged.update(rmap)
        maps[leaf] = merged
    sr = SourceRouting(CLUSTER512, maps=maps)
    for phase in ring_allreduce(p.gpus, 1.0)[:1]:
        assert contention(phase, sr).is_contention_free
    for phase in pairwise_alltoall(p.gpus, 1.0):
        assert contention(phase, sr).is_contention_free


def test_release_restores_capacity():
    st = fresh()
    p = vclos_place(st, 0, 128)
    commit(st, p)
    used = sum(sum(v.values()) for v in st.link_owner.values())
    assert used == 128
    release(st, 0)
    assert st.num_free_gpus() == CLUSTER512.num_gpus
    assert not st.link_owner


def test_factorizations_cover_160():
    # the Fig-12d 160-GPU job: 5 leafs x 32 spines (pure doubling misses it)
    f = _factorizations(160, CLUSTER512)
    assert (5, 32) in f


def test_candidate_sizes_bumps_awkward_n():
    sizes = candidate_sizes(72, CLUSTER512)  # 72 = 9x8: (9>L? no, 9 leafs ok)
    assert sizes[0] == 72
    f = _factorizations(72, CLUSTER512)
    assert f, "72 = 9 leafs x 8 GPUs should factor"


def test_ilp_agrees_with_greedy_feasibility():
    """When greedy succeeds, the ILP must also find a solution (both solve
    the same eq.(2)-(6) system)."""
    from repro.core.placement import _greedy_vclos, _ilp_vclos
    st = fresh()
    commit(st, vclos_place(st, 1, 64))
    cap = st.capacity()
    g = _greedy_vclos(st, 2, 32, cap)
    i = _ilp_vclos(st, 2, 32, cap)
    assert (g is None) == (i is None) or i is not None


def test_network_fragmentation_detected():
    """Consume links so GPUs exist but no aligned sub-Clos does."""
    st = fresh()
    placed = []
    jid = 0
    # fill most of the cluster with 32-GPU leaf jobs (no links used)
    for _ in range(14):
        p = vclos_place(st, jid, 32)
        if isinstance(p, PlacementFailure):
            break
        commit(st, p)
        placed.append(jid)
        jid += 1
    # now require a job too big for remaining aligned capacity
    res = vclos_place(st, 999, 128)
    assert isinstance(res, PlacementFailure)
