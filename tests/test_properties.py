"""Hypothesis property tests: the paper's theoretical core + the engines.

Part 1 — Lemma 5.1: *any* Leaf-wise Permutation phase is contention-free
under *any* source-routing strategy (injective per-leaf port→uplink maps).

Part 2 — simulator invariants under random traces *and random dynamic
events* (ISSUE 4): work conservation (every job finishes, no resource
leaks), isolated strategies never over-reserve a link, the applied-event
clock is monotone, and the v1 ≡ v2 engine bit-parity holds as a property —
so any violation hypothesis finds shrinks to a minimal regression repro.
"""

import numpy as np
import pytest

# Unlike tests/test_kernels.py (where only the @given tests need hypothesis
# and the example-based ones run regardless), every test in this module is a
# hypothesis property, so the module-level gate is the honest scope: without
# the optional extra there is nothing here to run.
hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the optional `hypothesis` extra")
from hypothesis import given, settings, strategies as st

from repro.core.config import SimConfig
from repro.core.events import FAIL_GPU_OWNER, FAIL_LINK_OWNER, ClusterEvent
from repro.core.jobs import Job
from repro.core.patterns import is_leafwise_permutation
from repro.core.routing import SourceRouting, contention
from repro.core.simulator import ClusterSimulator
from repro.core.topology import ClusterSpec
from repro.core.traffic import Flow

SPEC = ClusterSpec(num_leafs=4, num_spines=8, gpus_per_leaf=8,
                   gpus_per_server=4)


@st.composite
def leafwise_phase(draw):
    """Random Definition-1-conforming phase: pick an injective leaf→leaf
    relation, then wire distinct src/dst GPUs along it."""
    nl = SPEC.num_leafs
    per = SPEC.gpus_per_leaf
    # injective partial map on leafs (as a permutation restricted to a set)
    perm = draw(st.permutations(range(nl)))
    active = draw(st.lists(st.integers(0, nl - 1), min_size=1, max_size=nl,
                           unique=True))
    flows = []
    for j in active:
        k = perm[j]
        if k == j:
            continue
        nflows = draw(st.integers(1, per))
        srcs = draw(st.permutations(range(per)))[:nflows]
        dsts = draw(st.permutations(range(per)))[:nflows]
        for s_, d_ in zip(srcs, dsts):
            flows.append(Flow(j * per + s_, k * per + d_, 1.0))
    return flows


@st.composite
def random_port_maps(draw):
    maps = {}
    for leaf in range(SPEC.num_leafs):
        # random injective port -> spine assignment
        spines = draw(st.permutations(range(SPEC.num_spines)))
        maps[leaf] = {i: (spines[i], 0) for i in range(SPEC.gpus_per_leaf)}
    return maps


@settings(max_examples=200, deadline=None)
@given(phase=leafwise_phase(), maps=random_port_maps())
def test_lemma_5_1_any_source_routing(phase, maps):
    assert is_leafwise_permutation(phase, SPEC)
    sr = SourceRouting(SPEC, maps=maps)
    rep = contention(phase, sr)
    assert rep.is_contention_free, (
        f"Lemma 5.1 violated: load {rep.max_load} on {phase}")


@st.composite
def arbitrary_permutation_phase(draw):
    n = SPEC.num_gpus
    size = draw(st.integers(2, n))
    srcs = draw(st.permutations(range(n)))[:size]
    dsts = draw(st.permutations(range(n)))[:size]
    return [Flow(s, d, 1.0) for s, d in zip(srcs, dsts)]


@settings(max_examples=200, deadline=None)
@given(phase=arbitrary_permutation_phase())
def test_source_routing_bounds_contention_by_leaf_count(phase):
    """§5.3: even for non-conforming permutations, SR bounds worst-case
    link load by L (vs L·S under ECMP)."""
    sr = SourceRouting(SPEC)
    rep = contention(phase, sr)
    assert rep.max_load <= SPEC.num_leafs


@settings(max_examples=100, deadline=None)
@given(phase=arbitrary_permutation_phase())
def test_checker_soundness(phase):
    """If the checker accepts a phase, default SR must be contention-free
    (soundness of is_leafwise_permutation wrt Lemma 5.1)."""
    if is_leafwise_permutation(phase, SPEC):
        assert contention(phase, SourceRouting(SPEC)).is_contention_free


def test_checker_rejects_colliding_leaf_targets():
    per = SPEC.gpus_per_leaf
    phase = [Flow(0 * per + 0, 2 * per + 0, 1.0),
             Flow(1 * per + 0, 2 * per + 1, 1.0)]  # two leafs -> leaf 2
    assert not is_leafwise_permutation(phase, SPEC)


def test_checker_rejects_non_permutation():
    phase = [Flow(0, 9, 1.0), Flow(0, 10, 1.0)]
    assert not is_leafwise_permutation(phase, SPEC)


# ---------------------------------------------------------------------------
# Part 2 — simulator invariants under random traces + dynamic events.
# SPEC is the 32-GPU, 4-leaf cluster: small enough that hypothesis examples
# run in milliseconds, large enough that every placement stage (server,
# leaf, vClos, multi-leaf) and every event kind is reachable.
# ---------------------------------------------------------------------------

_EV_MODELS = ("resnet50", "vgg16", "moe")


@st.composite
def churn_scenario(draw):
    """A random job trace plus a random (self-recovering) event trace.

    Every generated failure pairs with a recovery, so the trace can never
    permanently shrink the cluster — the precondition of the work
    -conservation property.  Preempt/resize may target queued, finished or
    unknown job ids (the engines must treat those as no-ops).
    """
    n = draw(st.integers(2, 8))
    jobs = []
    t = 0.0
    for i in range(n):
        t += draw(st.floats(0.0, 60.0, allow_nan=False,
                            allow_infinity=False))
        jobs.append(Job(i, draw(st.sampled_from(_EV_MODELS)),
                        draw(st.sampled_from([1, 2, 4, 8, 16])), 32, t,
                        draw(st.integers(1, 200))))
    span = jobs[-1].arrival + 300.0
    events = []
    for _ in range(draw(st.integers(0, 8))):
        kind = draw(st.sampled_from(("preempt", "resize", "server-fail",
                                     "link-fail")))
        et = draw(st.floats(0.0, span, allow_nan=False,
                            allow_infinity=False))
        penalty = draw(st.floats(0.0, 100.0, allow_nan=False,
                                 allow_infinity=False))
        if kind == "preempt":
            events.append(ClusterEvent(time=et, kind="preempt",
                                       job_id=draw(st.integers(0, n + 1)),
                                       restart_iters=penalty))
        elif kind == "resize":
            events.append(ClusterEvent(
                time=et, kind="resize",
                job_id=draw(st.integers(0, n + 1)),
                new_gpus=draw(st.sampled_from([1, 2, 4, 8, 16, 32])),
                restart_iters=penalty))
        elif kind == "server-fail":
            sv = draw(st.integers(0, SPEC.num_servers - 1))
            dur = draw(st.floats(1.0, 400.0, allow_nan=False,
                                 allow_infinity=False))
            events.append(ClusterEvent(time=et, kind="server-fail",
                                       server=sv, restart_iters=penalty))
            events.append(ClusterEvent(time=et + dur, kind="server-recover",
                                       server=sv))
        else:
            lf = draw(st.integers(0, SPEC.num_leafs - 1))
            sp = draw(st.integers(0, SPEC.num_spines - 1))
            dur = draw(st.floats(1.0, 400.0, allow_nan=False,
                                 allow_infinity=False))
            events.append(ClusterEvent(time=et, kind="link-fail", leaf=lf,
                                       spine=sp, restart_iters=penalty))
            events.append(ClusterEvent(time=et + dur, kind="link-recover",
                                       leaf=lf, spine=sp))
    events.sort(key=lambda e: e.time)
    return jobs, tuple(events)


def _fresh(jobs):
    return [Job(j.job_id, j.model, j.num_gpus, j.batch_size, j.arrival,
                j.num_iters) for j in jobs]


@settings(max_examples=40, deadline=None)
@given(scenario=churn_scenario(),
       strategy=st.sampled_from(("ecmp", "sr", "best")),
       defrag=st.sampled_from((0.0, 150.0)))
def test_work_conservation_and_monotone_event_clock(scenario, strategy,
                                                    defrag):
    """Every failure recovers, so every job must eventually finish; the
    applied-event log must be time-ordered; no resource may leak past the
    run (only unexpired failure fences may remain)."""
    jobs, events = scenario
    sim = ClusterSimulator(SPEC, config=SimConfig(
        strategy=strategy, events=events, defrag_interval=defrag))
    rep = sim.run(_fresh(jobs))
    assert rep.n_finished == len(jobs)
    for j in sim._jobs_by_id.values():
        assert j.finish_time is not None
        assert j.start_time >= j.arrival
        assert j.finish_time >= j.start_time
    times = [e[0] for e in rep.event_log]
    assert times == sorted(times)
    assert all(0.0 <= f <= 1.0 for _, f in rep.frag_series)
    leaked = {g: o for g, o in sim.state.gpu_owner.items()
              if o != FAIL_GPU_OWNER}
    assert leaked == {}


@settings(max_examples=30, deadline=None)
@given(scenario=churn_scenario())
def test_isolated_strategy_never_over_reserves(scenario):
    """vClos under churn: reservations stay within link capacity at every
    instant (FabricState.reserve_links raises on violation, so surviving
    the run IS the property) and are fully returned afterwards."""
    jobs, events = scenario
    sim = ClusterSimulator(SPEC, config=SimConfig(
        strategy="vclos", events=events, defrag_interval=200.0))
    rep = sim.run(_fresh(jobs))
    assert rep.n_finished == len(jobs)
    for (n, m), holders in sim.state.link_owner.items():
        # only an unexpired link-failure fence may outlive the run
        assert set(holders) <= {FAIL_LINK_OWNER}
        assert sum(holders.values()) <= sim.state.capacity()[n][m]


@pytest.mark.slow
@settings(max_examples=30, deadline=None)
@given(scenario=churn_scenario(),
       strategy=st.sampled_from(("ecmp", "sr", "best", "vclos")))
def test_engine_bit_parity_is_a_property(scenario, strategy):
    """v1 ≡ v2 under arbitrary churn — hypothesis shrinks any divergence
    to a minimal trace, which becomes a free regression repro."""
    jobs, events = scenario
    cfg = SimConfig(strategy=strategy, events=events, defrag_interval=150.0)
    v1 = ClusterSimulator(SPEC, config=cfg, engine="v1").run(_fresh(jobs))
    v2 = ClusterSimulator(SPEC, config=cfg, engine="v2").run(_fresh(jobs))
    assert v1.jcts == v2.jcts
    assert v1.jwts == v2.jwts
    assert v1.slowdowns == v2.slowdowns
    assert v1.event_log == v2.event_log
    assert v1.frag_series == v2.frag_series


@st.composite
def quiet_trace(draw):
    """A random small churn-free trace — the batched lane engine's
    qualifying regime (fifo, no events, no defrag)."""
    n = draw(st.integers(1, 10))
    jobs = []
    t = 0.0
    for i in range(n):
        t += draw(st.floats(0.0, 40.0, allow_nan=False,
                            allow_infinity=False))
        jobs.append(Job(i, draw(st.sampled_from(_EV_MODELS)),
                        draw(st.sampled_from([1, 2, 4, 8, 16])), 32, t,
                        draw(st.integers(1, 150))))
    return jobs


@settings(max_examples=40, deadline=None)
@given(jobs=quiet_trace(),
       strategy=st.sampled_from(("ecmp", "sr", "best")),
       seed=st.integers(0, 3))
def test_batched_engine_parity_is_a_property(jobs, strategy, seed):
    """batched ≡ v2 on random small traces (docs/batched.md) — any lane
    -engine divergence shrinks to a minimal job list.  The fast-path
    strategies are the interesting case (the lane engine actually runs);
    the suite in tests/test_batched.py covers the delegating rest."""
    cfg = SimConfig(strategy=strategy, seed=seed)
    vb = ClusterSimulator(SPEC, config=cfg,
                          engine="batched").run(_fresh(jobs))
    v2 = ClusterSimulator(SPEC, config=cfg, engine="v2").run(_fresh(jobs))
    assert vb.n_finished == v2.n_finished == len(jobs)
    assert vb.jcts == v2.jcts
    assert vb.jwts == v2.jwts
    assert vb.slowdowns == v2.slowdowns


# ---------------------------------------------------------------------------
# Part 4 — fault-tolerant runtime (ISSUE 7): resume ≡ uninterrupted, as a
# property over random crash schedules
# ---------------------------------------------------------------------------

_RESUME_GRID = None
_RESUME_CLEAN = None


def _resume_baseline():
    """Clean campaign computed once (the property replays against it)."""
    global _RESUME_GRID, _RESUME_CLEAN
    if _RESUME_CLEAN is None:
        from repro.core import CampaignGrid, WorkloadSpec, run_campaign
        from repro.core.topology import CLUSTER512
        _RESUME_GRID = CampaignGrid(strategies=("ecmp", "sr"),
                                    loads=(120.0,), seeds=(0, 1))
        _RESUME_CLEAN = run_campaign(
            CLUSTER512, _RESUME_GRID,
            workload=WorkloadSpec(num_jobs=25, max_gpus=64),
            config=SimConfig(retry_backoff=0.0))
    return _RESUME_GRID, _RESUME_CLEAN


@settings(max_examples=10, deadline=None)
@given(crash_cells=st.sets(st.integers(0, 3), min_size=1, max_size=3),
       store=st.sampled_from(("full", "stream")))
def test_random_crash_schedule_resume_equals_clean(crash_cells, store,
                                                   tmp_path_factory):
    """Any set of deterministically-failing cells aborts the campaign;
    repeatedly resuming the journal with one fewer armed failure each
    round must converge to a result whose cells are bit-identical to an
    uninterrupted run (sample arrays compared exactly).  Exercises
    multi-failure resume chains the example-based chaos suite
    (tests/test_runtime.py) doesn't enumerate."""
    import os

    from repro.core import CampaignError, WorkloadSpec, run_campaign
    from repro.core.topology import CLUSTER512
    grid, clean_full = _resume_baseline()
    wl = WorkloadSpec(num_jobs=25, max_gpus=64)
    cfg = SimConfig(retry_backoff=0.0, max_retries=0, store=store)
    jp = str(tmp_path_factory.mktemp("chaos") / "journal.jsonl")
    armed = sorted(crash_cells)
    first = True
    try:
        while True:
            os.environ["REPRO_CHAOS"] = ",".join(
                f"raise@{c}" for c in armed) or "raise@999"
            kw = {"journal": jp} if first else {"resume": jp}
            first = False
            try:
                res = run_campaign(CLUSTER512, grid, workload=wl,
                                   config=cfg, **kw)
                break
            except CampaignError as e:
                key = e.failed.key()
                idx = [i for i, c in enumerate(grid.cells())
                       if c == key][0]
                assert idx in armed          # only armed cells may fail
                armed.remove(idx)
    finally:
        os.environ.pop("REPRO_CHAOS", None)
    assert res.complete and not res.failed_cells
    want = {(c.strategy, c.scheduler, c.load, c.seed): c.report
            for c in clean_full.cells}
    assert len(res.cells) == len(want)
    for c in res.cells:
        ref = want[(c.strategy, c.scheduler, c.load, c.seed)]
        assert c.report.n_finished == ref.n_finished
        if store == "full":
            assert c.report == ref           # exact, every field
        else:
            # streaming cells condense; the exact scalars must still match
            assert c.report.avg_jct == ref.avg_jct
            assert c.report.avg_jwt == ref.avg_jwt
            assert c.report.event_log == ref.event_log


# ---------------------------------------------------------------------------
# Part 5 — heterogeneous fabrics + time-domain interleaving (the hetero
# tentpole, tests/test_hetero.py): speed-aware fair share respects every
# capacity, straggler scaling is monotone, duty scoring is order-free
# ---------------------------------------------------------------------------


@st.composite
def capped_flow_problem(draw):
    """Random flow×link incidence + per-link capacities + a NIC cap."""
    nlinks = draw(st.integers(1, 6))
    nflows = draw(st.integers(1, 12))
    flow_links = [draw(st.lists(st.integers(0, nlinks - 1), min_size=0,
                                max_size=nlinks, unique=True))
                  for _ in range(nflows)]
    caps = {l: draw(st.floats(0.1, 4.0, allow_nan=False,
                              allow_infinity=False))
            for l in range(nlinks)}
    flow_cap = draw(st.floats(0.05, 2.0, allow_nan=False,
                              allow_infinity=False))
    return flow_links, caps, flow_cap


@settings(max_examples=150, deadline=None)
@given(problem=capped_flow_problem())
def test_speed_aware_fair_share_respects_every_capacity(problem):
    """The flow_cap-parametrised water-filling (the old hard-coded unit
    NIC bound, now spec-derived on hetero fabrics) may never allocate past
    *any* link's capacity nor past the per-flow NIC ceiling."""
    from repro.core.fairshare import maxmin_fair_numpy
    flow_links, caps, flow_cap = problem
    rates = maxmin_fair_numpy(flow_links, caps, flow_cap=flow_cap)
    assert np.all(rates >= 0.0)
    assert np.all(rates <= flow_cap + 1e-12)
    for link, cap in caps.items():
        used = sum(rates[i] for i, ls in enumerate(flow_links)
                   if link in ls)
        # progressive filling may fill a bottleneck exactly; only genuine
        # over-allocation (beyond float accumulation) is a violation
        assert used <= cap + 1e-9 * max(1, len(flow_links))


@settings(max_examples=60, deadline=None)
@given(model=st.sampled_from(_EV_MODELS),
       num_gpus=st.sampled_from([1, 2, 4, 8, 16]),
       s1=st.floats(0.05, 1.0, allow_nan=False, allow_infinity=False),
       s2=st.floats(0.05, 1.0, allow_nan=False, allow_infinity=False))
def test_straggler_scaling_is_monotone(model, num_gpus, s1, s2):
    """A slower slowest-member can never finish a job earlier: JCT is
    monotone non-increasing in the fleet's compute scale (the derivative
    of effective iteration time in compute time is ≥ 1 − β > 0)."""
    import dataclasses

    from repro.core.simulator import simulate
    lo, hi = min(s1, s2), max(s1, s2)
    jobs = [Job(0, model, num_gpus, 32, 0.0, 50)]

    def jct(scale):
        spec = dataclasses.replace(
            SPEC, server_scale=(scale,) * SPEC.num_servers)
        return simulate(spec, _fresh(jobs), "ecmp").jcts[0]

    assert jct(lo) >= jct(hi)


@settings(max_examples=200, deadline=None)
@given(duties=st.lists(st.floats(0.0, 1.0, allow_nan=False,
                                 allow_infinity=False), max_size=10),
       seed=st.integers(0, 2 ** 16))
def test_phase_offset_scoring_is_permutation_invariant(duties, seed):
    """duty_overflow is fsum-backed: any co-location order of the same
    resident duty cycles produces the identical score bit-for-bit, so the
    contention-affinity-time placement cannot depend on job arrival
    order-of-insertion."""
    from repro.core.patterns import duty_overflow
    rng = np.random.default_rng(seed)
    perm = [duties[i] for i in rng.permutation(len(duties))]
    assert duty_overflow(perm) == duty_overflow(duties)
    assert duty_overflow(duties) >= 0.0
