"""Hypothesis property tests on the paper's theoretical core.

Lemma 5.1: *any* Leaf-wise Permutation phase is contention-free under *any*
source-routing strategy (injective per-leaf port→uplink maps).
"""

import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the optional `hypothesis` extra")
from hypothesis import given, settings, strategies as st

from repro.core.patterns import is_leafwise_permutation
from repro.core.routing import SourceRouting, contention
from repro.core.topology import ClusterSpec
from repro.core.traffic import Flow

SPEC = ClusterSpec(num_leafs=4, num_spines=8, gpus_per_leaf=8,
                   gpus_per_server=4)


@st.composite
def leafwise_phase(draw):
    """Random Definition-1-conforming phase: pick an injective leaf→leaf
    relation, then wire distinct src/dst GPUs along it."""
    nl = SPEC.num_leafs
    per = SPEC.gpus_per_leaf
    # injective partial map on leafs (as a permutation restricted to a set)
    perm = draw(st.permutations(range(nl)))
    active = draw(st.lists(st.integers(0, nl - 1), min_size=1, max_size=nl,
                           unique=True))
    flows = []
    for j in active:
        k = perm[j]
        if k == j:
            continue
        nflows = draw(st.integers(1, per))
        srcs = draw(st.permutations(range(per)))[:nflows]
        dsts = draw(st.permutations(range(per)))[:nflows]
        for s_, d_ in zip(srcs, dsts):
            flows.append(Flow(j * per + s_, k * per + d_, 1.0))
    return flows


@st.composite
def random_port_maps(draw):
    maps = {}
    for leaf in range(SPEC.num_leafs):
        # random injective port -> spine assignment
        spines = draw(st.permutations(range(SPEC.num_spines)))
        maps[leaf] = {i: (spines[i], 0) for i in range(SPEC.gpus_per_leaf)}
    return maps


@settings(max_examples=200, deadline=None)
@given(phase=leafwise_phase(), maps=random_port_maps())
def test_lemma_5_1_any_source_routing(phase, maps):
    assert is_leafwise_permutation(phase, SPEC)
    sr = SourceRouting(SPEC, maps=maps)
    rep = contention(phase, sr)
    assert rep.is_contention_free, (
        f"Lemma 5.1 violated: load {rep.max_load} on {phase}")


@st.composite
def arbitrary_permutation_phase(draw):
    n = SPEC.num_gpus
    size = draw(st.integers(2, n))
    srcs = draw(st.permutations(range(n)))[:size]
    dsts = draw(st.permutations(range(n)))[:size]
    return [Flow(s, d, 1.0) for s, d in zip(srcs, dsts)]


@settings(max_examples=200, deadline=None)
@given(phase=arbitrary_permutation_phase())
def test_source_routing_bounds_contention_by_leaf_count(phase):
    """§5.3: even for non-conforming permutations, SR bounds worst-case
    link load by L (vs L·S under ECMP)."""
    sr = SourceRouting(SPEC)
    rep = contention(phase, sr)
    assert rep.max_load <= SPEC.num_leafs


@settings(max_examples=100, deadline=None)
@given(phase=arbitrary_permutation_phase())
def test_checker_soundness(phase):
    """If the checker accepts a phase, default SR must be contention-free
    (soundness of is_leafwise_permutation wrt Lemma 5.1)."""
    if is_leafwise_permutation(phase, SPEC):
        assert contention(phase, SourceRouting(SPEC)).is_contention_free


def test_checker_rejects_colliding_leaf_targets():
    per = SPEC.gpus_per_leaf
    phase = [Flow(0 * per + 0, 2 * per + 0, 1.0),
             Flow(1 * per + 0, 2 * per + 1, 1.0)]  # two leafs -> leaf 2
    assert not is_leafwise_permutation(phase, SPEC)


def test_checker_rejects_non_permutation():
    phase = [Flow(0, 9, 1.0), Flow(0, 10, 1.0)]
    assert not is_leafwise_permutation(phase, SPEC)
