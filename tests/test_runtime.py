"""Fault-tolerant campaign runtime (repro.core.runtime) + chaos harness.

The contract under test (ISSUE 7, docs/robustness.md): a campaign that
crashes, hangs, or raises mid-grid can be resumed from its cell journal
and the merged ``CampaignResult`` is **bit-identical** to an
uninterrupted run — across workers=1/4 and store full/stream.  Failures
are injected deterministically by cell index via ``REPRO_CHAOS``
(:mod:`repro.testing.chaos`), so every recovery path runs in CI without
flakiness.
"""

import dataclasses
import json
import os
import time

import pytest

from repro.core import (CLUSTER512, CampaignError, CampaignGrid, CellJournal,
                        JournalMismatch, MetricsReport, SimConfig,
                        WorkloadSpec, atomic_write_text, backoff_delay,
                        classify_exception, run_campaign)
from repro.testing.chaos import (ChaosError, TransientChaosError, chaos_hook,
                                 parse_chaos)

GRID = CampaignGrid(strategies=("ecmp", "sr"), loads=(120.0,), seeds=(0, 1))
WL = WorkloadSpec(num_jobs=30, max_gpus=64)
# retry_backoff=0: recovery paths shouldn't sleep in CI
FAST = dict(retry_backoff=0.0)


def run(**kw):
    cfg = SimConfig(**{**FAST, **kw.pop("cfg", {})})
    return run_campaign(CLUSTER512, GRID, workload=WL, config=cfg, **kw)


def cell_reports(res):
    return [(c.strategy, c.scheduler, c.load, c.seed, c.report)
            for c in res.cells]


def table_no_wall(res):
    # sim_seconds is wall-clock measurement, not simulation output — it can
    # never match across separate processes; everything else must, exactly
    return [{k: v for k, v in row.items() if k != "sim_seconds"}
            for row in res.aggregate()]


@pytest.fixture
def clean():
    return run()


# ---------------------------------------------------------------------------
# units: classification, backoff, chaos grammar, atomic writes
# ---------------------------------------------------------------------------

def test_classify_exception():
    assert classify_exception(OSError("boom")) == "transient"
    assert classify_exception(EOFError()) == "transient"
    assert classify_exception(MemoryError()) == "transient"
    assert classify_exception(ConnectionResetError()) == "transient"
    assert classify_exception(TransientChaosError("x")) == "transient"
    assert classify_exception(ValueError("bug")) == "error"
    assert classify_exception(ChaosError("x")) == "error"


def test_backoff_deterministic_bounded():
    d1 = backoff_delay(7, 3, 1, base=0.1)
    assert d1 == backoff_delay(7, 3, 1, base=0.1)       # seeded jitter
    assert d1 != backoff_delay(7, 3, 2, base=0.1)       # varies per attempt
    assert 0.1 <= d1 <= 0.125
    d2 = backoff_delay(7, 3, 2, base=0.1)
    assert 0.2 <= d2 <= 0.25                            # exponential
    assert backoff_delay(0, 0, 50, base=1.0) <= 30.0    # capped
    assert backoff_delay(0, 0, 1, base=0.0) == 0.0      # disabled


def test_parse_chaos_grammar():
    rules = parse_chaos("crash@3,flaky@7:2, hang@12 ,raise@0:1")
    assert [(r.kind, r.cell, r.attempts) for r in rules] == [
        ("crash", 3, None), ("flaky", 7, 2), ("hang", 12, None),
        ("raise", 0, 1)]
    assert rules[1].fires(7, 0) and rules[1].fires(7, 1)
    assert not rules[1].fires(7, 2) and not rules[1].fires(6, 0)
    for bad in ("boom@1", "crash", "crash@x", "crash@-1", "crash@1:0"):
        with pytest.raises(ValueError):
            parse_chaos(bad)


def test_chaos_crash_refused_in_main_process(monkeypatch):
    # a crash rule firing without a worker pool would kill the whole
    # campaign (journal and all) — the hook must refuse, not os._exit
    monkeypatch.setenv("REPRO_CHAOS", "crash@0")
    with pytest.raises(RuntimeError, match="refused"):
        chaos_hook(0, 0)


def test_atomic_write_text(tmp_path):
    p = tmp_path / "out.json"
    atomic_write_text(p, "first")
    atomic_write_text(p, "second")
    assert p.read_text() == "second"
    assert list(tmp_path.iterdir()) == [p]              # no .tmp leftovers


# ---------------------------------------------------------------------------
# journal: round-trip exactness, schema guard, torn-tail tolerance
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("condense", [False, True])
def test_metrics_journal_roundtrip_exact(condense):
    from repro.core import generate_trace, simulate
    rep = simulate(CLUSTER512, generate_trace(WL.with_seed(3)), "ecmp")
    rep.event_log = [(0.0, "preempt", 1, -1, 2)]        # tuples must survive
    if condense:
        rep.condense(max_samples=16)
    back = MetricsReport.from_journal(
        json.loads(json.dumps(rep.to_journal())))
    assert back == rep                                  # exact, field-for-field
    assert back.event_log == rep.event_log
    assert all(isinstance(e, tuple) for e in back.event_log)


def test_journal_create_refuses_existing(tmp_path):
    p = str(tmp_path / "j.jsonl")
    CellJournal.create(p, {"v": 1}).close()
    with pytest.raises(ValueError, match="resume"):
        CellJournal.create(p, {"v": 1})


def test_journal_schema_mismatch(tmp_path):
    p = str(tmp_path / "j.jsonl")
    CellJournal.create(p, {"grid": [1, 2], "store": "full"}).close()
    with pytest.raises(JournalMismatch, match="store"):
        CellJournal.resume(p, {"grid": [1, 2], "store": "stream"})
    # tuples vs lists must NOT mismatch (JSON-normalised comparison)
    jr, completed = CellJournal.resume(p, {"grid": (1, 2), "store": "full"})
    jr.close()
    assert completed == {}


def test_journal_torn_tail_dropped_midfile_corruption_raises(tmp_path):
    p = str(tmp_path / "j.jsonl")
    jr = CellJournal.create(p, {"v": 1})
    rep = MetricsReport(1.0, 2.0, 3.0, 0.0, 0.0, 1)
    jr.append(("ecmp", "fifo", 120.0, 0), rep, 0.5)
    jr.append(("sr", "fifo", 120.0, 0), rep, 0.5)
    jr.close()
    with open(p, "a") as f:
        f.write('{"kind": "cell", "cell": ["ecmp", "fifo"')   # torn tail
    jr2, completed = CellJournal.resume(p, {"v": 1})
    jr2.close()
    assert set(completed) == {("ecmp", "fifo", 120.0, 0),
                              ("sr", "fifo", 120.0, 0)}
    assert completed[("sr", "fifo", 120.0, 0)][0] == rep
    # resume truncated the torn bytes: every line on disk parses again
    lines = open(p).read().splitlines()
    assert all(json.loads(line) for line in lines)
    # the same torn line anywhere but the tail is external corruption
    lines.insert(1, '{"kind": "cell", "cell": ["ecmp", "fifo"')
    open(p, "w").write("\n".join(lines))
    with pytest.raises(ValueError, match="corrupt at line 2"):
        CellJournal.resume(p, {"v": 1})


def test_journal_torn_tail_truncated_then_reappend(tmp_path):
    """Regression: resume() must *truncate* the torn bytes, not just skip
    them — otherwise the first appended record concatenates onto the
    partial line, planting mid-file corruption that makes the next
    resume refuse with 'corrupt', losing access to every journaled cell."""
    p = str(tmp_path / "j.jsonl")
    jr = CellJournal.create(p, {"v": 1})
    rep = MetricsReport(1.0, 2.0, 3.0, 0.0, 0.0, 1)
    jr.append(("ecmp", "fifo", 120.0, 0), rep, 0.5)
    jr.close()
    with open(p, "a") as f:
        f.write('{"kind": "cell", "cell": ["sr", "fifo"')      # torn tail
    jr2, completed = CellJournal.resume(p, {"v": 1})
    assert set(completed) == {("ecmp", "fifo", 120.0, 0)}
    jr2.append(("sr", "fifo", 120.0, 0), rep, 0.5)             # re-simulated
    jr2.close()
    jr3, completed = CellJournal.resume(p, {"v": 1})           # crash again
    jr3.close()
    assert set(completed) == {("ecmp", "fifo", 120.0, 0),
                              ("sr", "fifo", 120.0, 0)}
    assert completed[("sr", "fifo", 120.0, 0)][0] == rep


def test_journal_missing_final_newline_restored(tmp_path):
    """A write torn between the JSON and its "\\n" terminator leaves a
    complete final record with no newline: the record must be kept and
    the terminator restored so the next append starts a fresh line."""
    p = str(tmp_path / "j.jsonl")
    jr = CellJournal.create(p, {"v": 1})
    rep = MetricsReport(1.0, 2.0, 3.0, 0.0, 0.0, 1)
    jr.append(("ecmp", "fifo", 120.0, 0), rep, 0.5)
    jr.close()
    with open(p, "r+b") as f:                   # tear off just the "\n"
        f.truncate(os.path.getsize(p) - 1)
    jr2, completed = CellJournal.resume(p, {"v": 1})
    assert set(completed) == {("ecmp", "fifo", 120.0, 0)}      # record kept
    jr2.append(("sr", "fifo", 120.0, 0), rep, 0.5)
    jr2.close()
    jr3, completed = CellJournal.resume(p, {"v": 1})
    jr3.close()
    assert set(completed) == {("ecmp", "fifo", 120.0, 0),
                              ("sr", "fifo", 120.0, 0)}


def test_journal_fsync_opt_in(tmp_path, monkeypatch):
    """``fsync=True`` (ISSUE 8: the scheduler-service event log) must
    fsync once per appended record — and per the header — while the
    default flush-only mode never calls fsync at all."""
    calls = []
    real_fsync = os.fsync
    monkeypatch.setattr(os, "fsync",
                        lambda fd: (calls.append(fd), real_fsync(fd))[1])
    rep = MetricsReport(1.0, 2.0, 3.0, 0.0, 0.0, 1)

    jr = CellJournal.create(str(tmp_path / "flush.jsonl"), {"v": 1})
    jr.append(("ecmp", "fifo", 120.0, 0), rep, 0.5)
    jr.close()
    assert calls == []                          # default: flush, no fsync

    jr = CellJournal.create(str(tmp_path / "sync.jsonl"), {"v": 1},
                            fsync=True)
    assert len(calls) == 1                      # header synced
    jr.append(("ecmp", "fifo", 120.0, 0), rep, 0.5)
    jr.append(("sr", "fifo", 120.0, 0), rep, 0.5)
    assert len(calls) == 3                      # one per record
    jr.close()

    # resume keeps the knob
    jr2, completed = CellJournal.resume(str(tmp_path / "sync.jsonl"),
                                        {"v": 1}, fsync=True)
    assert len(completed) == 2
    n = len(calls)
    jr2.append(("ecmp", "ff", 120.0, 0), rep, 0.5)
    assert len(calls) == n + 1
    jr2.close()


# ---------------------------------------------------------------------------
# serial campaigns: resume bit-identity, retries, quarantine
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("store", ["full", "stream"])
def test_crash_at_cell_resume_bit_identical_serial(clean, tmp_path,
                                                   monkeypatch, store):
    """Deterministic failure at cell 2 aborts with a journal holding the
    finished cells; the resumed run merges bit-identically to clean."""
    jp = str(tmp_path / "c.jsonl")
    monkeypatch.setenv("REPRO_CHAOS", "raise@2")
    with pytest.raises(CampaignError) as ei:
        run(journal=jp, cfg=dict(store=store))
    assert ei.value.failed.kind == "error"
    assert jp in str(ei.value)                      # actionable resume hint
    monkeypatch.delenv("REPRO_CHAOS")
    res = run(resume=jp, cfg=dict(store=store))
    base = run(cfg=dict(store=store)) if store != "full" else clean
    assert res.resumed_cells == 2
    assert cell_reports(res) == cell_reports(base)
    assert table_no_wall(res) == table_no_wall(base)


def test_resume_from_complete_journal(clean, tmp_path):
    jp = str(tmp_path / "c.jsonl")
    run(journal=jp)
    res = run(resume=jp)
    assert res.resumed_cells == GRID.size and res.complete
    assert cell_reports(res) == cell_reports(clean)


def test_flaky_cell_retried_to_success(clean, monkeypatch):
    monkeypatch.setenv("REPRO_CHAOS", "flaky@1:2")      # 2 transient fails
    res = run()                                          # default 2 retries
    assert cell_reports(res) == cell_reports(clean)
    # one more transient failure than retries -> permanent
    monkeypatch.setenv("REPRO_CHAOS", "flaky@1:3")
    with pytest.raises(CampaignError) as ei:
        run()
    assert ei.value.failed.kind == "transient"
    assert ei.value.failed.attempts == 3


def test_quarantine_accounting(clean, monkeypatch):
    monkeypatch.setenv("REPRO_CHAOS", "raise@1")
    res = run(quarantine=True)
    assert len(res.cells) == GRID.size - 1
    assert [f.kind for f in res.failed_cells] == ["error"]
    fc = res.failed_cells[0]
    assert (fc.strategy, fc.scheduler, fc.load, fc.seed) in set(GRID.cells())
    assert res.missing_cells() == [fc.key()] and not res.complete
    # surviving cells are untouched by the neighbour's failure
    want = {(c.strategy, c.scheduler, c.load, c.seed): c.report
            for c in clean.cells}
    for c in res.cells:
        assert c.report == want[(c.strategy, c.scheduler, c.load, c.seed)]
    j = res.to_json()
    assert j["failed_cells"][0]["kind"] == "error"
    assert j["missing_cells"] == [list(fc.key())]
    assert j["resumed_cells"] == 0
    # the aggregate row for the quarantined slice pools one seed less
    row = next(r for r in res.aggregate()
               if (r["strategy"], r["scheduler"]) == (fc.strategy,
                                                      fc.scheduler))
    assert row["seeds"] == 1


def test_journal_resume_arg_validation(tmp_path):
    with pytest.raises(ValueError, match="not two different paths"):
        run(journal=str(tmp_path / "a"), resume=str(tmp_path / "b"))
    with pytest.raises(ValueError, match="does not exist"):
        run(resume=str(tmp_path / "missing.jsonl"))
    jp = str(tmp_path / "j.jsonl")
    run(journal=jp)
    # a journal written by a different campaign is refused with a diff
    # (grid seeds override the workload seed, so vary the trace length)
    with pytest.raises(JournalMismatch, match="traces"):
        run_campaign(CLUSTER512, GRID,
                     workload=dataclasses.replace(WL, num_jobs=25),
                     config=SimConfig(**FAST), resume=jp)


def test_campaign_result_save_atomic(tmp_path, clean):
    out = tmp_path / "res.json"
    clean.save(str(out))
    data = json.loads(out.read_text())
    assert data["resumed_cells"] == 0 and data["missing_cells"] == []
    assert not (tmp_path / "res.json.tmp").exists()
    clean.write_csv(str(tmp_path / "res.csv"))
    assert (tmp_path / "res.csv").read_text().startswith("strategy,")


# ---------------------------------------------------------------------------
# pool campaigns: worker death, isolation, timeouts (slow: real processes)
# ---------------------------------------------------------------------------

def test_shutdown_pool_kills_hung_workers():
    """Regression: _shutdown_pool(kill=True) must terminate the worker
    *processes* (an operator-precedence bug once made it iterate raw PIDs,
    so terminate() never ran and hung workers leaked past the kill)."""
    from concurrent.futures import ProcessPoolExecutor

    from repro.core.runtime import _shutdown_pool
    pool = ProcessPoolExecutor(max_workers=2)
    pool.submit(time.sleep, 300)                # hang both workers
    pool.submit(time.sleep, 300)
    deadline = time.monotonic() + 10.0
    while len(pool._processes or {}) < 2 and time.monotonic() < deadline:
        time.sleep(0.05)
    procs = list(pool._processes.values())
    assert procs
    _shutdown_pool(pool, kill=True)
    for p in procs:
        p.join(timeout=10.0)
        assert not p.is_alive()                 # dead, not sleeping out 300s

@pytest.mark.slow
@pytest.mark.parametrize("store", ["full", "stream"])
def test_worker_crash_resume_bit_identical_pool(tmp_path, monkeypatch,
                                                store):
    """A worker killed mid-campaign (os._exit, as an OOM kill would)
    surfaces as a crash, is isolated and retried; with the crash armed on
    every attempt the cell poisons out, and resuming the journal without
    chaos merges bit-identically to a clean run — workers=4."""
    base = run(cfg=dict(store=store))
    jp = str(tmp_path / "p.jsonl")
    monkeypatch.setenv("REPRO_CHAOS", "crash@2")
    with pytest.raises(CampaignError) as ei:
        run(journal=jp, cfg=dict(store=store, workers=4))
    assert ei.value.failed.kind == "crash"
    monkeypatch.delenv("REPRO_CHAOS")
    res = run(resume=jp, cfg=dict(store=store, workers=4))
    assert cell_reports(res) == cell_reports(base)
    assert table_no_wall(res) == table_no_wall(base)


@pytest.mark.slow
def test_worker_crash_once_recovers_via_isolation(clean, monkeypatch):
    """crash@2:1 kills whichever workers are in flight alongside cell 2;
    the runner isolates the suspects, attributes the crash, and the retry
    (attempt 1, rule expired) completes the full grid bit-identically —
    innocent cells never burn an attempt."""
    monkeypatch.setenv("REPRO_CHAOS", "crash@2:1")
    res = run(cfg=dict(workers=4))
    assert cell_reports(res) == cell_reports(clean)
    assert res.complete and not res.failed_cells


@pytest.mark.slow
def test_hung_cell_timeout_quarantined(clean, monkeypatch):
    """A hung worker trips cell_timeout, the pool is killed (the only way
    to stop it), the cell quarantines as `timeout`, and the innocent
    cells complete unharmed."""
    monkeypatch.setenv("REPRO_CHAOS", "hang@0")
    monkeypatch.setenv("REPRO_CHAOS_HANG", "60")
    res = run(cfg=dict(workers=2, cell_timeout=3.0, max_retries=0,
                       quarantine=True))
    assert [f.kind for f in res.failed_cells] == ["timeout"]
    assert "cell_timeout" in res.failed_cells[0].error
    want = {(c.strategy, c.scheduler, c.load, c.seed): c.report
            for c in clean.cells}
    assert len(res.cells) == GRID.size - 1
    for c in res.cells:
        assert c.report == want[(c.strategy, c.scheduler, c.load, c.seed)]


@pytest.mark.slow
def test_hung_cell_timeout_retry_recovers(clean, monkeypatch):
    """hang@3:1 hangs only the first attempt; cell_timeout kills it and
    the retry completes — also proves cell_timeout>0 forces the pool path
    at workers=1 (the serial path could never interrupt the hang)."""
    monkeypatch.setenv("REPRO_CHAOS", "hang@3:1")
    monkeypatch.setenv("REPRO_CHAOS_HANG", "60")
    res = run(cfg=dict(cell_timeout=3.0))
    assert cell_reports(res) == cell_reports(clean)
    assert res.complete and not res.failed_cells


# ---------------------------------------------------------------------------
# partial figures / reports: gaps render visibly, gates refuse silence
# ---------------------------------------------------------------------------

def test_partial_figure_gap_annotation(monkeypatch):
    from repro.core import build_figure, qualitative_checks
    from repro.launch.report import render_markdown
    monkeypatch.setenv("REPRO_CHAOS", "raise@3")
    tab = build_figure("jct-vs-load", scale="smoke",
                       fault=dict(quarantine=True, max_retries=0,
                                  retry_backoff=0.0))
    meta = tab.meta_dict()
    assert meta["missing_cells"] == 1 and meta["failed_cells"] == 1
    assert meta["grid_cells"] == 8
    # gates refuse silently-incomplete data...
    problems = qualitative_checks([tab])
    assert problems and "incomplete" in problems[0]
    # ...allow_partial renders it, but never silently
    assert qualitative_checks([tab], allow_partial=True) == []
    md = render_markdown([tab], "smoke")
    assert "Partial data" in md and "1 of 8 grid cells missing" in md


def test_complete_figure_has_no_partial_meta():
    # the committed (byte-gated) gallery must not change on the clean
    # path: partial-accounting keys appear only when cells are missing
    from repro.core import build_figure
    tab = build_figure("ocs-comparison", scale="smoke")
    meta = tab.meta_dict()
    assert "missing_cells" not in meta and "failed_cells" not in meta


def test_figure_journal_resume_dir(tmp_path, monkeypatch):
    from repro.core import build_figure
    monkeypatch.setenv("REPRO_CHAOS", "raise@3")
    with pytest.raises(CampaignError):
        build_figure("jct-vs-load", scale="smoke",
                     fault=dict(retry_backoff=0.0, max_retries=0),
                     resume_dir=str(tmp_path))
    assert (tmp_path / "jct-vs-load.journal.jsonl").exists()
    monkeypatch.delenv("REPRO_CHAOS")
    resumed = build_figure("jct-vs-load", scale="smoke",
                           resume_dir=str(tmp_path))
    assert resumed == build_figure("jct-vs-load", scale="smoke")


# ---------------------------------------------------------------------------
# CLI validation (mirrors the --events pattern: actionable argparse errors)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("argv", [
    ["--cell-timeout", "0"],
    ["--cell-timeout", "-2"],
    ["--max-retries", "-1"],
    ["--resume", "/nonexistent/journal.jsonl"],
    ["--journal", "/tmp/a.jsonl", "--resume", "/tmp/b.jsonl"],
])
def test_sweep_campaign_cli_validation(argv, capsys):
    from repro.launch.sweep import campaign_main
    with pytest.raises(SystemExit) as ei:
        campaign_main(argv)
    assert ei.value.code == 2
    err = capsys.readouterr().err
    assert argv[0].lstrip("-").split()[0] in err.replace("_", "-") \
        or "journal" in err


def test_sweep_campaign_cli_journal_exists(tmp_path, capsys):
    jp = tmp_path / "exists.jsonl"
    jp.write_text("{}\n")
    from repro.launch.sweep import campaign_main
    with pytest.raises(SystemExit) as ei:
        campaign_main(["--journal", str(jp)])
    assert ei.value.code == 2
    assert "--resume" in capsys.readouterr().err
