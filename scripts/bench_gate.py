#!/usr/bin/env python
"""Perf gate: fail when the recorded campaign benchmark regresses.

Reads the committed ``BENCH_campaign.json`` (written by ``make bench-json``
via the paired-median protocol — never single timings on this noisy box)
and exits non-zero when:

  1. ``campaign_engine[overall].meets_5x_vs_seed_baseline`` is false —
     the v2 heap engine lost its 5x geomean over the seed full-recompute
     algorithm on the gated strategies (ecmp, sr), or
  2. any per-strategy ``identical_jct`` flag is false — the engines
     stopped producing bit-identical schedules, or
  3. the parallel 2-worker cell stopped merging identically to serial, or
  4. a ``bench_batched[lane_engine]`` cell is present but its
     ``meets_3x_on_64cell_grid`` flag is false — the lane-batched engine
     lost its 3x median speedup over the serial v2 loop on the ≥64-cell
     acceptance grid (older recordings without the cell are tolerated,
     matching the report_suite pattern), or
  5. a ``campaign_resume[overhead]`` cell is present but the cell
     journal's overhead exceeded 5% of campaign wall time, or resuming a
     completed journal stopped reproducing the fresh run bit-identically
     (the PR 7 fault-tolerance gates; older recordings tolerated), or
  6. a ``bench_service`` cell is present but ``replay_identical`` is
     false — the scheduler service's event loop diverged from offline
     ``simulate()`` — or ``meets_service_p99_bound`` is false — the
     client-observed placement p99 under load exceeded its recorded
     bound (the ISSUE 8 online-service gates; older recordings
     tolerated), or
  7. a ``bench_hetero[rate_resolution]`` cell is present but its
     ``hetero_ratio_le_1_3x`` flag is false — the speed-aware hetero
     rate-resolution path costs more than 1.3x the homogeneous
     arithmetic on the 144-cell acceptance grid, or its
     ``identical_jct`` flag is false — the degenerate hetero spec
     stopped reproducing the homogeneous schedule bit-for-bit
     (docs/heterogeneous.md; older recordings tolerated), or
  8. a ``bench_traces`` cell is present but ``stream_eq_eager`` is
     false — the streaming trace reader diverged from the eager loader
     on a shared prefix — or ``rss_within_bound`` is false — the
     million-job windowed replay's peak RSS exceeded its recorded bound
     (the trace-ingestion gates, docs/traces.md; older recordings
     tolerated).

Run: python scripts/bench_gate.py [PATH]   (or: make bench-gate)
"""

from __future__ import annotations

import json
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent


def main() -> int:
    path = Path(sys.argv[1]) if len(sys.argv) > 1 \
        else ROOT / "BENCH_campaign.json"
    if not path.exists():
        print(f"bench-gate: FAILED — {path} missing (run `make bench-json`)")
        return 1
    summary = json.loads(path.read_text()).get("engine_summary", {})
    errors = []

    overall = summary.get("campaign_engine[overall]")
    if overall is None:
        errors.append("campaign_engine[overall] row missing")
    elif not overall.get("meets_5x_vs_seed_baseline"):
        errors.append(
            f"meets_5x_vs_seed_baseline regressed to false "
            f"(geomean vs seed: "
            f"{overall.get('speedup_vs_seed_full_recompute')}x)")

    for name, row in sorted(summary.items()):
        if "identical_jct" in row and not row["identical_jct"]:
            errors.append(f"{name}: engines no longer bit-identical")
        if "identical_to_serial" in row and not row["identical_to_serial"]:
            errors.append(f"{name}: parallel merge no longer matches serial")
        # report_suite cells (benchmarks/bench_report.py) are optional —
        # absent in older recordings and in --only runs — but when present
        # their honesty flags gate like the engine ones
        if "golden_ok" in row and not row["golden_ok"]:
            errors.append(f"{name}: docs/results.md gallery drifted from "
                          f"the regenerated smoke figures")
        if "orderings_ok" in row and not row["orderings_ok"]:
            errors.append(f"{name}: reproduced figures lost the paper's "
                          f"qualitative orderings")
        # bench_batched cells gate only when present (PR 6+): the lane
        # engine must keep its 3x-vs-serial-v2 acceptance margin
        if "meets_3x_on_64cell_grid" in row \
                and not row["meets_3x_on_64cell_grid"]:
            errors.append(
                f"{name}: lane-batched engine below 3x vs serial v2 "
                f"(median: {row.get('speedup_vs_serial_v2')}x on "
                f"{row.get('cells')} cells)")
        # campaign_resume cells gate only when present (PR 7+): the cell
        # journal must stay cheap and resume must stay bit-identical
        if "journal_overhead_le_5pct" in row \
                and not row["journal_overhead_le_5pct"]:
            errors.append(
                f"{name}: cell journal overhead above 5% of campaign "
                f"wall time ({row.get('journal_overhead_pct')}% on "
                f"{row.get('cells')} cells)")
        if "resume_identical" in row and not row["resume_identical"]:
            errors.append(
                f"{name}: resuming a completed journal no longer "
                f"reproduces the fresh run bit-identically")
        # bench_service cells gate only when present (PR 8+): the online
        # service must stay bit-identical to offline simulate() and keep
        # its placement tail-latency bound under concurrent load
        if "replay_identical" in row and not row["replay_identical"]:
            errors.append(
                f"{name}: service event loop no longer replays "
                f"bit-identically to offline simulate()")
        if "meets_service_p99_bound" in row \
                and not row["meets_service_p99_bound"]:
            errors.append(
                f"{name}: placement p99 {row.get('place_p99_ms')}ms "
                f"above the {row.get('p99_bound_ms')}ms bound "
                f"({row.get('queries')} queries over "
                f"{row.get('connections')} connections)")
        # bench_hetero cells gate only when present (ISSUE 10+): the
        # speed-aware rate path must stay within 1.3x of the homogeneous
        # arithmetic (its degenerate bit-identity rides the generic
        # identical_jct check above)
        if "hetero_ratio_le_1_3x" in row \
                and not row["hetero_ratio_le_1_3x"]:
            errors.append(
                f"{name}: hetero rate resolution above 1.3x homogeneous "
                f"(median: {row.get('hetero_over_homog_ratio')}x on "
                f"{row.get('cells')} cells)")
        # bench_traces cells gate only when present (ISSUE 9+): streaming
        # ingestion must match the eager loader and stay inside its
        # recorded peak-RSS bound on the million-job windowed replay
        if "stream_eq_eager" in row and not row["stream_eq_eager"]:
            errors.append(
                f"{name}: streaming trace reader no longer matches the "
                f"eager loader on a shared prefix")
        if "rss_within_bound" in row and not row["rss_within_bound"]:
            errors.append(
                f"{name}: windowed million-job replay peak RSS "
                f"{row.get('peak_rss_mb')}MB above the "
                f"{row.get('rss_bound_mb')}MB bound")

    if errors:
        print("bench-gate: FAILED")
        for e in errors:
            print(f"  - {e}")
        return 1
    print(f"bench-gate: OK ({overall['speedup_vs_seed_full_recompute']}x "
          f"geomean vs seed baseline, engines bit-identical)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
