#!/usr/bin/env python
"""End-to-end smoke for the scheduler daemon (``make service-smoke``).

Starts the real asyncio TCP server on an ephemeral port with a durable
event log in a temp dir, then drives one scripted client session through
every protocol op: stats, admission (grant + quota deny), submits (placed,
queued-by-quota), a what-if query (twice — the second must be a memo
hit), a churn event, a clock advance, and a clean ``shutdown``.  Finally
it reopens the event log to prove the session replays to the same fabric
version.  Any assertion or protocol error exits 1.

Run: python scripts/service_smoke.py   (or: make service-smoke)
"""

from __future__ import annotations

import sys
import tempfile
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "src"))


def main() -> int:
    from repro.core import CLUSTER512, SimConfig
    from repro.service import (LiveCluster, SchedClient, SchedulerService,
                               ServerThread, ServiceError)

    with tempfile.TemporaryDirectory(prefix="service_smoke_") as td:
        log = str(Path(td) / "schedd.log")
        cfg = SimConfig(strategy="sr", scheduler="fifo", seed=0, engine="v2")
        live = LiveCluster.open(log, CLUSTER512, cfg,
                                quotas={"teamA": 64}, fsync=False)
        server = ServerThread(SchedulerService(live))
        host, port = server.start()
        print(f"  daemon up on {host}:{port} (event log {log})")

        with SchedClient(host, port) as c:
            s = c.stats()
            assert s["running"] == 0 and s["version"] == 0, s

            # admission: unlimited tenant ok, quota tenant denied over cap
            assert c.admit("default", 128)["admit"]
            denied = c.admit("teamA", 128)
            assert not denied["admit"] and "quota" in denied["reason"], denied

            # submit: placed immediately on an empty cluster
            r = c.submit("resnet50", 16, 4000, tenant="teamA")
            assert r["admitted"] and r["placed"] and r["kind"], r
            print(f"  job {r['job_id']} placed ({r['kind']}, "
                  f"{len(r['gpus'])} GPUs)")

            # quota enforcement on the submit path: denied, not placed,
            # but still journalled (the log is a pure input stream)
            d = c.submit("bert", 64, 1000, tenant="teamA")
            assert not d["admitted"] and "quota" in d["reason"], d

            # protocol errors answer ok:false without tearing the session
            try:
                c.place("no-such-model", 8, 100)
            except ServiceError as e:
                assert "no-such-model" in str(e), e
            else:
                raise AssertionError("unknown model accepted")

            # what-if: cold then memo-hit at the same fabric version
            w = c.whatif("moe", 32, 2000, strategies=["sr", "ecmp"])
            for name in ("sr", "ecmp"):
                pred = w["strategies"][name]
                assert pred["supported"] and pred["placed_now"], (name, pred)
            assert not w["cached"]
            assert c.whatif("moe", 32, 2000,
                            strategies=["sr", "ecmp"])["cached"]
            jct = w["strategies"]["sr"]["predicted_jct"]
            print(f"  what-if: predicted JCT {jct:.1f}s under sr "
                  f"(memo hit confirmed)")

            # churn event + clock advance through the protocol
            ev = c.event({"time": 100.0, "kind": "preempt",
                          "job_id": r["job_id"], "restart_iters": 50.0})
            assert ev["kind"] == "preempt", ev
            adv = c.advance(200.0)
            assert adv["t"] == 200.0, adv

            version = c.stats()["version"]
            c.shutdown()
        server.join()
        print(f"  clean shutdown at fabric version {version}")

        # crash-resume contract: reopening the log replays to the same state
        live2 = LiveCluster.open(log, CLUSTER512, cfg,
                                 quotas={"teamA": 64}, fsync=False)
        assert live2.version == version, (live2.version, version)
        assert live2.now == 200.0, live2.now
        live2.close()
        print(f"  event-log replay reproduced version {live2.version} "
              f"at t={live2.now:g}")

    print("service-smoke: OK")
    return 0


if __name__ == "__main__":
    try:
        sys.exit(main())
    except AssertionError as e:
        print(f"service-smoke: FAILED: {e}")
        sys.exit(1)
