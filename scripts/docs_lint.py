#!/usr/bin/env python
"""Documentation lint: keep README/docs honest against the code.

Checks:
  1. required docs exist (README, docs/{architecture,simulator,batched,
     strategies,events,reproduction,robustness,service,results}.md)
  2. every `src/...` path mentioned in them exists on disk
  3. relative markdown links resolve
  4. the README strategy glossary covers every simulator strategy
  5. fenced ``python`` snippets in the docs at least compile
  6. the generated results gallery is in sync: the smoke figure suite is
     regenerated (seconds) and ``docs/results.md`` + the committed smoke
     CSVs must match byte-for-byte (``repro.launch.report.check_results``)

Run: python scripts/docs_lint.py   (or: make docs-lint)
Skip the slow drift check during doc-only editing: --no-results
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
DOCS = ["README.md", "docs/architecture.md", "docs/simulator.md",
        "docs/batched.md", "docs/strategies.md", "docs/events.md",
        "docs/reproduction.md", "docs/robustness.md", "docs/service.md",
        "docs/traces.md", "docs/heterogeneous.md", "docs/results.md"]

errors: list[str] = []


def check(cond: bool, msg: str) -> None:
    if not cond:
        errors.append(msg)


def main() -> int:
    texts = {}
    for rel in DOCS:
        path = ROOT / rel
        check(path.exists(), f"missing required doc: {rel}")
        if path.exists():
            texts[rel] = path.read_text()

    # 2. referenced source paths exist
    for rel, text in texts.items():
        for m in re.finditer(r"`((?:src|benchmarks|examples|tests|scripts)"
                             r"/[\w/.-]+\.(?:py|md))`", text):
            check((ROOT / m.group(1)).exists(),
                  f"{rel}: dangling path reference `{m.group(1)}`")

    # 3. relative markdown links resolve
    for rel, text in texts.items():
        base = (ROOT / rel).parent
        for m in re.finditer(r"\]\((?!https?://|#)([^)]+?)(?:#[^)]*)?\)", text):
            target = m.group(1)
            check((base / target).exists() or (ROOT / target).exists(),
                  f"{rel}: broken link -> {target}")

    # 4. strategy glossary is complete
    sys.path.insert(0, str(ROOT / "src"))
    from repro.core.simulator import STRATEGIES
    from repro.core.scheduler import QUEUE_POLICIES
    readme = texts.get("README.md", "")
    for s in STRATEGIES:
        check(f"`{s}`" in readme, f"README.md: strategy `{s}` missing "
                                  f"from the glossary")
    for q in QUEUE_POLICIES:
        check(f"`{q}`" in readme, f"README.md: queueing policy `{q}` "
                                  f"missing")

    # 5. python snippets compile
    for rel, text in texts.items():
        for i, m in enumerate(re.finditer(r"```python\n(.*?)```", text,
                                          re.DOTALL)):
            try:
                compile(m.group(1), f"{rel}[snippet {i}]", "exec")
            except SyntaxError as e:
                check(False, f"{rel}: snippet {i} does not compile: {e}")

    # 6. generated results gallery in sync with a regenerated smoke run
    checked_results = "--no-results" not in sys.argv
    if checked_results:
        from repro.launch.report import check_results
        for e in check_results():
            check(False, e)

    if errors:
        print("docs-lint: FAILED")
        for e in errors:
            print(f"  - {e}")
        return 1
    n_snippets = sum(len(re.findall(r"```python", t)) for t in texts.values())
    print(f"docs-lint: OK ({len(texts)} docs, {n_snippets} snippets, "
          f"results gallery {'in sync' if checked_results else 'UNCHECKED'})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
