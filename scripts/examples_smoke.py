#!/usr/bin/env python
"""Examples stay runnable against the live API (``make examples-smoke``).

Three layers, cheapest first:

  1. every ``examples/*.py`` byte-compiles;
  2. every ``import repro...`` / ``from repro... import name`` statement in
     them resolves against the installed package — renamed/removed API
     fails here without executing the example;
  3. the cheap examples actually run end-to-end in a subprocess
     (``contention_analysis.py``, ``multi_tenant_cluster.py --jobs 12``),
     and the argparse-guarded heavy ones at least parse ``--help`` (which
     executes their module-level imports for real).

``quickstart.py`` and ``train_lm.py`` train models (~25 s each), so their
full runs are opt-in: ``EXAMPLES_FULL=1 python scripts/examples_smoke.py``.

Run: python scripts/examples_smoke.py   (or: make examples-smoke)
"""

from __future__ import annotations

import ast
import importlib
import os
import py_compile
import subprocess
import sys
import time
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
EXAMPLES = sorted((ROOT / "examples").glob("*.py"))

#: fully executed every run (cheap); None = no extra argv
RUN_FULL = {"contention_analysis.py": [],
            "multi_tenant_cluster.py": ["--jobs", "12"]}
#: heavy examples: --help executes module-level imports, then exits
RUN_HELP = {"train_lm.py"}
#: heavy examples run only under EXAMPLES_FULL=1
RUN_OPT_IN = {"quickstart.py": [], "train_lm.py": ["--tiny", "--steps", "2"]}

errors: list[str] = []


def check_imports(path: Path) -> None:
    """Resolve the example's repro.* imports without executing it."""
    tree = ast.parse(path.read_text(), filename=str(path))
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.name.split(".")[0] == "repro":
                    importlib.import_module(a.name)
        elif isinstance(node, ast.ImportFrom) and node.module \
                and node.module.split(".")[0] == "repro":
            mod = importlib.import_module(node.module)
            for a in node.names:
                if not hasattr(mod, a.name):
                    # a submodule is importable but not yet an attribute
                    importlib.import_module(f"{node.module}.{a.name}")


def run_example(path: Path, argv: list[str]) -> None:
    t0 = time.time()
    try:
        r = subprocess.run(
            [sys.executable, str(path)] + argv, cwd=ROOT, timeout=600,
            env={**os.environ, "PYTHONPATH": str(ROOT / "src")},
            capture_output=True, text=True)
    except subprocess.TimeoutExpired:
        errors.append(f"{path.name} {' '.join(argv)}: timeout > 600s")
        return
    if r.returncode != 0:
        tail = (r.stderr or r.stdout).strip().splitlines()[-8:]
        errors.append(f"{path.name} {' '.join(argv)}: exit {r.returncode}\n"
                      + "\n".join(f"      {ln}" for ln in tail))
    else:
        print(f"  ran {path.name} {' '.join(argv)} "
              f"[{time.time() - t0:.1f}s]")


def main() -> int:
    sys.path.insert(0, str(ROOT / "src"))
    full = os.environ.get("EXAMPLES_FULL") == "1"
    for path in EXAMPLES:
        try:
            py_compile.compile(str(path), doraise=True)
        except py_compile.PyCompileError as e:
            errors.append(f"{path.name}: does not compile: {e.msg}")
            continue
        try:
            check_imports(path)
        except Exception as e:
            errors.append(f"{path.name}: import smoke failed: "
                          f"{type(e).__name__}: {e}")
            continue
        if path.name in RUN_FULL:
            run_example(path, RUN_FULL[path.name])
        elif path.name in RUN_HELP:
            run_example(path, ["--help"])
        if full and path.name in RUN_OPT_IN:
            run_example(path, RUN_OPT_IN[path.name])
    if errors:
        print("examples-smoke: FAILED")
        for e in errors:
            print(f"  - {e}")
        return 1
    print(f"examples-smoke: OK ({len(EXAMPLES)} examples"
          f"{', full runs included' if full else ''})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
