"""Table 6 — job-scheduler sensitivity: FIFO / EDF / FF (fewest-GPU-first)."""

from __future__ import annotations

from repro.core import CLUSTER512, CLUSTER512_OCS, cluster_dataset, simulate

from .common import N_JOBS_FAST, N_JOBS_FULL, timed

STRATS = ("ocs-vclos", "vclos", "best", "sr", "ecmp")


def run(fast: bool = True):
    n_jobs = N_JOBS_FAST if fast else N_JOBS_FULL
    jobs = cluster_dataset(num_jobs=n_jobs, lam=120.0, seed=0,
                           with_deadlines=True)
    rows = []
    for sched in ("fifo", "edf", "ff"):
        for strat in STRATS:
            spec = CLUSTER512_OCS if strat == "ocs-vclos" else CLUSTER512
            def work(s=strat, sc=sched, sp=spec):
                rep = simulate(sp, jobs, s, scheduler=sc)
                return {"avg_jct": round(rep.avg_jct, 1)}
            rows.append(timed(f"table6_sched[{sched},{strat}]", work))
    return rows


if __name__ == "__main__":
    from .common import emit
    emit(run())
