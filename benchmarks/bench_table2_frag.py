"""Table 2 — fragmentation counts (GPU vs network) for vClos / OCS-vClos
across arrival rates λ."""

from __future__ import annotations

from repro.core import CLUSTER512, CLUSTER512_OCS, cluster_dataset, simulate

from .common import N_JOBS_FAST, N_JOBS_FULL, timed


def run(fast: bool = True):
    n_jobs = N_JOBS_FAST if fast else N_JOBS_FULL
    lams = (100, 120) if fast else (100, 110, 120, 130)
    rows = []
    for lam in lams:
        jobs = cluster_dataset(num_jobs=n_jobs, lam=float(lam), seed=0)
        for strat, spec in (("vclos", CLUSTER512),
                            ("ocs-vclos", CLUSTER512_OCS)):
            def work(j=jobs, s=strat, sp=spec):
                rep = simulate(sp, j, s)
                return {"frag_gpu": rep.frag_gpu,
                        "frag_network": rep.frag_network}
            rows.append(timed(f"table2_frag[lam={lam},{strat}]", work))
    return rows


if __name__ == "__main__":
    from .common import emit
    emit(run())
