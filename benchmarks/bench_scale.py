"""Production-trace scale benchmark: 10k jobs on the 2048-GPU cluster.

The v2 heap engine's asymptotic wins (O(log R) event selection, memoised
placement retries, batched rate solves) only show at trace sizes the v1
scan engine struggles with.  This benchmark:

(1) completes one 10k-job / 2048-GPU campaign cell through
    ``run_campaign`` on the v2 engine with streaming aggregation
    (``store="stream"`` — O(512) retained samples, not O(10k)), and
(2) reports the paired v2-vs-v1 speedup on that trace (one back-to-back
    pair per repeat; median) with the bit-identity check.

  PYTHONPATH=src python -m benchmarks.bench_scale [--full]
"""

from __future__ import annotations

import time

from repro.core import (CLUSTER2048, CampaignGrid, WorkloadSpec,
                        generate_trace, run_campaign, simulate)

from .common import timed

WORKLOAD = WorkloadSpec(num_jobs=10_000, mean_interarrival=30.0,
                        max_gpus=1024, seed=0)
STRAT = "ecmp"          # the rate-resolution workout


def run(fast: bool = True):
    rows = []

    # -- (1) the 10k-job campaign cell, streaming ---------------------------
    def cell():
        grid = CampaignGrid(strategies=(STRAT,),
                            loads=(WORKLOAD.mean_interarrival,), seeds=(0,))
        res = run_campaign(CLUSTER2048, grid, workload=WORKLOAD,
                           store="stream")
        row = res.aggregate()[0]
        rep = res.cells[0].report
        return {"jobs": WORKLOAD.num_jobs, "gpus": CLUSTER2048.num_gpus,
                "engine": "v2", "store": "stream",
                "n_finished": row["n_finished"],
                "jct_mean": round(row["jct_mean"], 1),
                "jct_p99": round(row["jct_p99"], 1),
                "retained_samples": len(rep.jcts),
                "completed": row["n_finished"] == WORKLOAD.num_jobs}
    rows.append(timed(f"scale_campaign_cell[{WORKLOAD.num_jobs}jobs"
                      f"x{CLUSTER2048.num_gpus}gpus]", cell))

    # -- (2) paired v2-vs-v1 on the 10k trace -------------------------------
    trace = generate_trace(WORKLOAD)
    repeats = 1 if fast else 3
    ratios, t_v2_best, rep = [], float("inf"), {}
    for _ in range(repeats):
        t0 = time.time()
        rep["v2"] = simulate(CLUSTER2048, trace, STRAT, engine="v2")
        t_v2 = time.time() - t0
        t0 = time.time()
        rep["v1"] = simulate(CLUSTER2048, trace, STRAT, engine="v1")
        ratios.append((time.time() - t0) / t_v2)
        t_v2_best = min(t_v2_best, t_v2)
    ratios.sort()
    rows.append({
        "name": f"scale_engine[{STRAT}]",
        "us_per_call": round(t_v2_best * 1e6, 1),
        "derived": {"engine": "v2", "jobs": WORKLOAD.num_jobs,
                    "gpus": CLUSTER2048.num_gpus,
                    "speedup_vs_v1": round(ratios[len(ratios) // 2], 2),
                    "identical_jct": rep["v2"].jcts == rep["v1"].jcts},
    })
    return rows


if __name__ == "__main__":
    import argparse

    from .common import emit
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="3 paired repeats instead of 1")
    args = ap.parse_args()
    emit(run(fast=not args.full))
