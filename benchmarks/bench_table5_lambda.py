"""Table 5 — Avg.JCT vs arrival rate λ per strategy (workload sensitivity),
including the OCS-Relax (locality relaxed) cautionary column."""

from __future__ import annotations

from repro.core import CLUSTER512, CLUSTER512_OCS, cluster_dataset, simulate

from .common import N_JOBS_FAST, N_JOBS_FULL, timed

STRATS = ("ocs-vclos", "vclos", "best", "sr", "balanced", "ecmp", "ocs-relax")


def run(fast: bool = True):
    n_jobs = N_JOBS_FAST if fast else N_JOBS_FULL
    lams = (120, 140) if fast else (100, 110, 120, 130, 140)
    rows = []
    for lam in lams:
        jobs = cluster_dataset(num_jobs=n_jobs, lam=float(lam), seed=0)
        for strat in STRATS:
            spec = CLUSTER512_OCS if strat.startswith("ocs") else CLUSTER512
            def work(s=strat, sp=spec, j=jobs):
                rep = simulate(sp, j, s)
                return {"avg_jct": round(rep.avg_jct, 1)}
            rows.append(timed(f"table5_jct[lam={lam},{strat}]", work))
    return rows


if __name__ == "__main__":
    from .common import emit
    emit(run())
