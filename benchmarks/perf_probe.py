"""§Perf probe: compile one unrolled cell variant and decompose its cost.

  PYTHONPATH=src python -m benchmarks.perf_probe --arch qwen1.5-32b \
      --shape train_4k [--layers 1] [--mb 1] [--remat full] [--no-fsdp] ...

Prints per-collective wire bytes, FLOPs, HBM bytes — the measurement side
of the hypothesis→change→measure loop in EXPERIMENTS.md §Perf.
"""

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, get_config
from repro.configs.base import RunConfig
from repro.launch.dryrun import (_aux_ctx, _small_cfg, decode_state_specs,
                                 sharded_param_specs)
from repro.launch.hlo_analysis import parse_collectives, cost_summary
from repro.launch.mesh import make_production_mesh
from repro.parallel.sharding import (abstract_params, input_shardings,
                                     input_specs, make_context)
from repro.train.optimizer import AdamWState, OptimizerConfig, adamw_init
from repro.train.train_step import make_train_step

import dataclasses as dc


def probe(arch: str, shape_name: str, layers: int, mb: int,
          remat: str = "full", fsdp: bool = True, seq_par: bool = True,
          batch: int = 0, opt_dtype: str = "float32",
          ssm_chunk: int = 0, block: int = 0) -> dict:
    cfg = _small_cfg(get_config(arch), layers)
    shape_cfg = SHAPES[shape_name]
    if batch:
        shape_cfg = dc.replace(shape_cfg, global_batch=batch)
    mesh = make_production_mesh(multi_pod=False)
    run_cfg = RunConfig(remat=remat, sequence_parallel=seq_par)
    ctx = _aux_ctx(make_context(mesh, cfg, run_cfg), shape_cfg)
    if ssm_chunk:
        ctx = dc.replace(ctx, ssm_chunk=ssm_chunk)
    if block:
        ctx = dc.replace(ctx, block_q=block, block_k=block)
    view = ctx.mesh
    params_abs = abstract_params(cfg, dtype=jnp.bfloat16)
    pshard = sharded_param_specs(params_abs, cfg, view, fsdp=fsdp)
    t0 = time.time()
    if shape_cfg.mode == "train":
        opt_cfg = OptimizerConfig(state_dtype=opt_dtype)
        step = make_train_step(cfg, opt_cfg, ctx=ctx, microbatches=mb,
                               unroll=True, grad_shardings=pshard)
        opt_abs = jax.eval_shape(lambda p: adamw_init(p, opt_cfg), params_abs)
        oshard = (None if opt_dtype == "int8" else
                  AdamWState(step=NamedSharding(view, P()), m=pshard,
                             v=pshard))
        batch_abs = input_specs(cfg, shape_cfg)
        bshard = input_shardings(cfg, shape_cfg, view)
        fn = jax.jit(step, in_shardings=(pshard, oshard, None, bshard),
                     out_shardings=(pshard, oshard, None, None),
                     donate_argnums=(0, 1))
        compiled = fn.lower(params_abs, opt_abs, None, batch_abs).compile()
    elif shape_cfg.mode == "prefill":
        from repro.models.transformer import forward

        def pf(params, b):
            extras = {k: v for k, v in b.items() if k != "tokens"}
            return forward(params, cfg, b["tokens"], ctx=ctx, **extras)[0]
        batch_abs = input_specs(cfg, shape_cfg)
        bshard = input_shardings(cfg, shape_cfg, view)
        compiled = jax.jit(pf, in_shardings=(pshard, bshard)).lower(
            params_abs, batch_abs).compile()
    else:
        from repro.serve.decode import decode_step
        state_abs, sshard = decode_state_specs(cfg, shape_cfg, view)
        tok_abs = jax.ShapeDtypeStruct((shape_cfg.global_batch, 1), jnp.int32)
        dp = int(np.prod([view.shape[n] for n in view.axis_names
                          if n in ("pod", "data")]))
        dpax = tuple(n for n in view.axis_names if n in ("pod", "data"))
        tshard = NamedSharding(view, P(
            dpax if shape_cfg.global_batch % dp == 0 else None, None))
        compiled = jax.jit(
            lambda p, t, s: decode_step(p, cfg, t, s, ctx=ctx),
            in_shardings=(pshard, tshard, sshard),
            donate_argnums=(2,)).lower(params_abs, tok_abs, state_abs).compile()
    costs = cost_summary(compiled)
    coll = parse_collectives(compiled.as_text())
    return {
        "compile_s": round(time.time() - t0, 1),
        "flops": costs.get("flops", 0.0),
        "hbm_bytes": costs.get("bytes accessed", 0.0),
        "wire_by_op": {k: round(v / 1e9, 3) for k, v in
                       coll.wire_bytes.items()},
        "counts": dict(coll.count),
        "total_wire_gb": round(coll.total_wire_bytes / 1e9, 3),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--layers", type=int, default=1)
    ap.add_argument("--mb", type=int, default=1)
    ap.add_argument("--remat", default="full")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--no-sp", action="store_true")
    ap.add_argument("--batch", type=int, default=0)
    ap.add_argument("--opt-dtype", default="float32")
    ap.add_argument("--ssm-chunk", type=int, default=0)
    ap.add_argument("--block", type=int, default=0)
    args = ap.parse_args()
    out = probe(args.arch, args.shape, args.layers, args.mb,
                remat=args.remat, fsdp=not args.no_fsdp,
                seq_par=not args.no_sp, batch=args.batch,
                opt_dtype=args.opt_dtype, ssm_chunk=args.ssm_chunk,
                block=args.block)
    print(json.dumps(out, indent=1))


if __name__ == "__main__":
    main()
