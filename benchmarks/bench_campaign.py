"""Campaign engine benchmark — the tentpole acceptance run.

(1) End-to-end campaign: a 512-GPU, ≥500-job Poisson trace simulated across
    four strategies (best / sr / ecmp / ocs-relax) through
    ``repro.core.campaign.run_campaign``.
(2) Engine speedup: the same trace replayed under the incremental-rate
    engine vs the full-recompute baseline (the seed algorithm: rebuild the
    global link load and re-solve every running job at every event) for the
    contention baselines that exercise rate re-solving (ecmp, sr), asserting
    bit-identical JCT output.  ``ocs-relax`` is also reported as the
    documented worst case: its scattered placement yields a dense contention
    graph where the affected set approaches the running set, so the
    incremental engine degrades gracefully to ~1x (never slower).

  PYTHONPATH=src python -m benchmarks.bench_campaign [--full]
"""

from __future__ import annotations

import time

from repro.core import (CLUSTER512, CampaignGrid, WorkloadSpec,
                        generate_trace, run_campaign, simulate)

from .common import timed

STRATS_E2E = ("best", "sr", "ecmp", "ocs-relax")
SPEEDUP_STRATS = ("ecmp", "sr")      # rate-engine workout (locality-packed)
WORST_CASE_STRATS = ("ocs-relax",)   # dense contention graph


def run(fast: bool = True):
    rows = []
    n_jobs = 500 if fast else 1000
    workload = WorkloadSpec(num_jobs=n_jobs, mean_interarrival=120.0,
                            max_gpus=256, seed=0)

    # -- (1) end-to-end campaign across strategies --------------------------
    def campaign():
        res = run_campaign(CLUSTER512, CampaignGrid(strategies=STRATS_E2E),
                           workload=workload)
        return {r["strategy"]: {"jct_mean": round(r["jct_mean"], 1),
                                "jct_p99": round(r["jct_p99"], 1),
                                "queue_delay_mean":
                                    round(r["queue_delay_mean"], 1),
                                "contention":
                                    round(r["contention_ratio_mean"], 3)}
                for r in res.aggregate()}
    rows.append(timed(f"campaign_cluster512[{n_jobs}jobs]", campaign))

    # -- (2) incremental engine vs full-recompute baseline ------------------
    # Paired timing: each repeat runs (incremental, full) back-to-back and
    # contributes one ratio, so machine-wide slow patches cancel; the median
    # over repeats is the reported speedup.
    trace = generate_trace(workload)
    simulate(CLUSTER512, trace[:40], "ecmp")    # warm caches/allocators
    repeats = 5
    speedups = []
    for strat in SPEEDUP_STRATS + WORST_CASE_STRATS:
        ratios, t_inc, rep = [], float("inf"), {}
        for _ in range(repeats):
            t0 = time.time()
            rep[True] = simulate(CLUSTER512, trace, strat, incremental=True)
            ti = time.time() - t0
            t0 = time.time()
            rep[False] = simulate(CLUSTER512, trace, strat, incremental=False)
            ratios.append((time.time() - t0) / ti)
            t_inc = min(t_inc, ti)
        ratios.sort()
        speedup = ratios[len(ratios) // 2]
        identical = (rep[True].jcts == rep[False].jcts
                     and rep[True].n_finished == rep[False].n_finished)
        if strat in SPEEDUP_STRATS:
            speedups.append(speedup)
        rows.append({
            "name": f"campaign_engine[{strat}]",
            "us_per_call": round(t_inc * 1e6, 1),
            "derived": {"speedup_vs_full_recompute": round(speedup, 2),
                        "identical_jct": identical},
        })
    overall = 1.0
    for s in speedups:
        overall *= s
    overall **= 1.0 / len(speedups)
    rows.append({
        "name": "campaign_engine[overall]",
        "us_per_call": 0.0,
        "derived": {"speedup_vs_full_recompute": round(overall, 2),
                    "meets_2x_target": bool(overall >= 2.0)},
    })
    return rows


if __name__ == "__main__":
    import argparse

    from .common import emit
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="1000-job trace instead of 500")
    args = ap.parse_args()
    emit(run(fast=not args.full))
