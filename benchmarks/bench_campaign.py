"""Campaign engine benchmark — the tentpole acceptance run.

(1) End-to-end campaign: a 512-GPU, ≥500-job Poisson trace simulated across
    five strategies (best / sr / ecmp / ocs-relax / contention-affinity)
    through ``repro.core.campaign.run_campaign`` on the v2 heap engine, so
    the affinity plugin's cost relative to ecmp/sr is on record from day
    one.
(2) Engine speedup, paired-median protocol: each repeat runs the v2 heap
    engine, the v1 scan engine, and the v1 full-recompute mode (the seed
    algorithm — the same fixed baseline PR 1 measured its 2.1x against)
    back-to-back, contributing one ratio per comparison; the median over
    repeats is reported, so machine-wide slow patches cancel.  JCT output
    must be bit-identical across all three.  ``ocs-relax`` is the
    documented worst case: its scattered placement yields a dense
    contention graph, so incremental re-solving degrades gracefully.
(3) Parallel-path smoke: a tiny 2-worker v2 campaign must merge
    bit-identically to the serial run (guards the ProcessPoolExecutor
    sharding in ``make bench-smoke``).
(4) Journal overhead + resume identity: on a 144-cell grid the cell
    journal (``repro.core.runtime.CellJournal``) must cost ≤5% of
    campaign wall time, and resuming a completed journal must reproduce
    the fresh run bit-identically (the PR 7 fault-tolerance gates).

  PYTHONPATH=src python -m benchmarks.bench_campaign [--full]
"""

from __future__ import annotations

import dataclasses
import os
import tempfile
import time

from repro.core import (CLUSTER512, CampaignGrid, SimConfig, WorkloadSpec,
                        generate_events, generate_trace, run_campaign,
                        simulate)

from .common import timed

STRATS_E2E = ("best", "sr", "ecmp", "ocs-relax", "contention-affinity")
SPEEDUP_STRATS = ("ecmp", "sr")      # rate-engine workout (locality-packed)
WORST_CASE_STRATS = ("ocs-relax",)   # dense contention graph
# measured alongside but excluded from the 5x gate so the gated geomean
# stays comparable across PRs (the PR 1/2 baseline was ecmp+sr only)
EXTRA_STRATS = ("contention-affinity",)


def run(fast: bool = True):
    rows = []
    n_jobs = 500 if fast else 1000
    workload = WorkloadSpec(num_jobs=n_jobs, mean_interarrival=120.0,
                            max_gpus=256, seed=0)

    # -- (1) end-to-end campaign across strategies --------------------------
    def campaign():
        res = run_campaign(CLUSTER512, CampaignGrid(strategies=STRATS_E2E),
                           workload=workload)
        return {r["strategy"]: {"jct_mean": round(r["jct_mean"], 1),
                                "jct_p99": round(r["jct_p99"], 1),
                                "queue_delay_mean":
                                    round(r["queue_delay_mean"], 1),
                                "contention":
                                    round(r["contention_ratio_mean"], 3)}
                for r in res.aggregate()}
    rows.append(timed(f"campaign_cluster512[{n_jobs}jobs]", campaign))

    # -- (2) v2 heap engine vs v1 scan engine (paired) ----------------------
    trace = generate_trace(workload)
    simulate(CLUSTER512, trace[:40], "ecmp")    # warm caches/allocators
    repeats = 5
    vs_v1, vs_seed = [], []
    for strat in SPEEDUP_STRATS + WORST_CASE_STRATS + EXTRA_STRATS:
        r_v1, r_seed, t_v2_best, rep = [], [], float("inf"), {}
        for _ in range(repeats):
            t0 = time.time()
            rep["v2"] = simulate(CLUSTER512, trace, strat, engine="v2")
            t_v2 = time.time() - t0
            t0 = time.time()
            rep["v1"] = simulate(CLUSTER512, trace, strat, engine="v1")
            r_v1.append((time.time() - t0) / t_v2)
            t0 = time.time()
            rep["seed"] = simulate(CLUSTER512, trace, strat, engine="v1",
                                   incremental=False)
            r_seed.append((time.time() - t0) / t_v2)
            t_v2_best = min(t_v2_best, t_v2)
        r_v1.sort()
        r_seed.sort()
        med_v1 = r_v1[len(r_v1) // 2]
        med_seed = r_seed[len(r_seed) // 2]
        identical = (rep["v2"].jcts == rep["v1"].jcts == rep["seed"].jcts
                     and rep["v2"].n_finished == rep["v1"].n_finished)
        if strat in SPEEDUP_STRATS:
            vs_v1.append(med_v1)
            vs_seed.append(med_seed)
        rows.append({
            "name": f"campaign_engine[{strat}]",
            "us_per_call": round(t_v2_best * 1e6, 1),
            "derived": {"engine": "v2", "jobs": n_jobs, "gpus": 512,
                        "speedup_vs_v1": round(med_v1, 2),
                        "speedup_vs_seed_full_recompute": round(med_seed, 2),
                        "identical_jct": identical},
        })

    def geomean(xs):
        p = 1.0
        for x in xs:
            p *= x
        return p ** (1.0 / len(xs))

    rows.append({
        "name": "campaign_engine[overall]",
        "us_per_call": 0.0,
        "derived": {"engine": "v2", "jobs": n_jobs, "gpus": 512,
                    "speedup_vs_v1": round(geomean(vs_v1), 2),
                    "speedup_vs_seed_full_recompute":
                        round(geomean(vs_seed), 2),
                    # explicit about the baseline: the 5x gate is against
                    # the seed full-recompute algorithm (the fixed
                    # reference PR 1 reported its 2.1x on); the v2-vs-v1
                    # ratio is reported alongside, ungated (~2.2-3x here,
                    # ~4-5x at bench_scale's 10k-job size)
                    "meets_5x_vs_seed_baseline":
                        bool(geomean(vs_seed) >= 5.0)},
    })

    # -- (2b) churn trace: dynamic events + defrag through both engines ----
    # measured alongside but excluded from the gated 5x geomean (like
    # contention-affinity) — the event path has no seed-baseline to compare
    # against; its identical_jct flag IS gate-enforced
    churn_wl = dataclasses.replace(workload, preempt_fraction=0.15,
                                   resize_fraction=0.08,
                                   server_mtbf=6000.0, link_mtbf=8000.0,
                                   fail_duration=2400.0)
    churn_trace = generate_trace(churn_wl)
    churn_events = tuple(generate_events(churn_wl, churn_trace, CLUSTER512))
    cfg = SimConfig(strategy="ecmp", events=churn_events,
                    defrag_interval=10000.0)
    r_v1, t_v2_best, rep = [], float("inf"), {}
    for _ in range(repeats):
        t0 = time.time()
        rep["v2"] = simulate(CLUSTER512, churn_trace, config=cfg,
                             engine="v2")
        t_v2 = time.time() - t0
        t0 = time.time()
        rep["v1"] = simulate(CLUSTER512, churn_trace, config=cfg,
                             engine="v1")
        r_v1.append((time.time() - t0) / t_v2)
        t_v2_best = min(t_v2_best, t_v2)
    r_v1.sort()
    rows.append({
        "name": "campaign_churn[ecmp]",
        "us_per_call": round(t_v2_best * 1e6, 1),
        "derived": {"engine": "v2", "jobs": n_jobs, "gpus": 512,
                    "events": len(churn_events),
                    "preemptions": rep["v2"].preemptions,
                    "failures": rep["v2"].failures,
                    "resizes": rep["v2"].resizes,
                    "speedup_vs_v1": round(r_v1[len(r_v1) // 2], 2),
                    "identical_jct":
                        bool(rep["v2"].jcts == rep["v1"].jcts
                             and rep["v2"].event_log == rep["v1"].event_log
                             and rep["v2"].n_finished
                             == rep["v1"].n_finished)},
    })

    # -- (3) parallel campaign path: 2 workers ≡ serial ---------------------
    def parallel_cell():
        grid = CampaignGrid(strategies=("ecmp", "sr"), loads=(150.0,),
                            seeds=(0,))
        small = WorkloadSpec(num_jobs=60, max_gpus=64, seed=0)
        ser = run_campaign(CLUSTER512, grid, workload=small)
        par = run_campaign(CLUSTER512, grid, workload=small, workers=2)
        same = all(a.report.jcts == b.report.jcts
                   for a, b in zip(ser.cells, par.cells))
        return {"workers": 2, "identical_to_serial": same}
    rows.append(timed("campaign_parallel[2workers]", parallel_cell))

    # -- (4) journal overhead + resume identity (fault-tolerant runtime) ----
    # the PR 7 acceptance cell: on a 144-cell grid, journaling every
    # completed cell must cost ≤5% of campaign wall time, and resuming a
    # complete journal must reproduce the fresh run's reports
    # bit-identically.  Overhead comes from the journal's own in-run
    # accounting (CellJournal.io_seconds: serialise + write + flush per
    # record) over the same run's wall clock — differencing two separate
    # end-to-end timings would put a ±20% machine-noise floor under a 5%
    # gate.  The paired wall ratio is reported alongside, ungated.
    resume_grid = CampaignGrid(strategies=("best", "vclos", "sr", "ecmp"),
                               loads=(200.0, 120.0, 80.0),
                               seeds=tuple(range(12)))       # 144 cells
    cell_wl = WorkloadSpec(num_jobs=24, max_gpus=64, seed=0)
    jrepeats = 3 if fast else 5
    overheads, ratios, t_plain_best = [], [], float("inf")
    tdir = tempfile.mkdtemp(prefix="bench-journal-")
    jp = plain = None
    for k in range(jrepeats):
        t0 = time.time()
        plain = run_campaign(CLUSTER512, resume_grid, workload=cell_wl)
        t_plain = time.time() - t0
        jp = os.path.join(tdir, f"j{k}.jsonl")
        t0 = time.time()
        jres = run_campaign(CLUSTER512, resume_grid, workload=cell_wl,
                            journal=jp)
        t_j = time.time() - t0
        overheads.append(jres.journal_seconds
                         / max(t_j - jres.journal_seconds, 1e-9))
        ratios.append(t_j / t_plain)
        t_plain_best = min(t_plain_best, t_plain)
    ratios.sort()
    overhead_pct = min(overheads) * 100.0
    resumed = run_campaign(CLUSTER512, resume_grid, workload=cell_wl,
                           resume=jp)
    resume_identical = (
        resumed.resumed_cells == resume_grid.size
        and all(a.report == b.report
                for a, b in zip(plain.cells, resumed.cells)))
    rows.append({
        "name": "campaign_resume[overhead]",
        "us_per_call": round(t_plain_best * 1e6, 1),
        "derived": {"cells": resume_grid.size,
                    "jobs_per_cell": cell_wl.num_jobs,
                    "repeats": jrepeats,
                    "journal_overhead_pct": round(overhead_pct, 2),
                    "wall_ratio_median":
                        round(ratios[len(ratios) // 2], 3),
                    "journal_overhead_le_5pct": bool(overhead_pct <= 5.0),
                    "resume_identical": bool(resume_identical)},
    })
    return rows


if __name__ == "__main__":
    import argparse

    from .common import emit
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="1000-job trace instead of 500")
    args = ap.parse_args()
    emit(run(fast=not args.full))
