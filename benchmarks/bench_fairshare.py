"""Max-min fair solver: numpy↔JAX crossover microbenchmark.

Times the sparse numpy water-filling against the dense jitted JAX kernel
over growing flow×link incidences, reports the measured auto-dispatch
crossover (``repro.core.fairshare.maxmin_fair_auto``), and does the same
for the v2 engine's batched bottleneck solve (``phase_worst_loads``).
Agreement between backends is asserted as part of the run — a divergence
raises and fails the harness (1e-6 here; tests/test_simulator.py pins
1e-9 on float64-representable cases).

  PYTHONPATH=src python -m benchmarks.bench_fairshare [--full]
"""

from __future__ import annotations

import time

import numpy as np

from repro.core.fairshare import (autotune_crossover, maxmin_fair_jax,
                                  maxmin_fair_numpy, phase_worst_accel,
                                  phase_worst_jax, phase_worst_numpy,
                                  problem_size)

#: (nvals, nsegments) CSR shapes observed at :func:`phase_worst_loads`
#: dispatch inside ``run_lanes`` on a fabric-heavy 72-lane campaign
#: (CLUSTER512, 300 jobs/lane, max_gpus=64, best/sr/ecmp × 8 seeds ×
#: 3 loads): the batched engine concatenates every affected job of every
#: lane into one call, so these are far larger than the per-event v2
#: shapes the old 4096-val probe modelled.
BATCHED_DISPATCH_SHAPES = (
    ("p50", 3345, 62),
    ("p90", 22652, 398),
    ("max", 43593, 753),
)


def _best_of(fn, *args, n: int = 3) -> float:
    fn(*args)                     # warm (JIT / allocator)
    best = float("inf")
    for _ in range(n):
        t0 = time.perf_counter()
        fn(*args)
        best = min(best, time.perf_counter() - t0)
    return best


def run(fast: bool = True):
    rows = []
    rng = np.random.default_rng(0)
    sizes = (64, 512, 2048) if fast else (64, 512, 2048, 8192)
    for nflows in sizes:
        flow_links = [rng.choice(64, size=3, replace=False).tolist()
                      for _ in range(nflows)]
        t_np = _best_of(maxmin_fair_numpy, flow_links)
        t_jx = _best_of(maxmin_fair_jax, flow_links)
        agree = float(np.abs(maxmin_fair_numpy(flow_links)
                             - maxmin_fair_jax(flow_links)).max())
        assert agree < 1e-6, \
            f"maxmin backends diverge at {nflows} flows: {agree}"
        rows.append({
            "name": f"maxmin_fair[{nflows}flows]",
            "us_per_call": round(min(t_np, t_jx) * 1e6, 1),
            "derived": {"size": problem_size(flow_links),
                        "numpy_us": round(t_np * 1e6, 1),
                        "jax_us": round(t_jx * 1e6, 1),
                        "jax_wins": bool(t_jx < t_np),
                        "max_abs_diff": agree},
        })

    nvals = 4096 if fast else 65536
    vals = rng.integers(1, 40, nvals).astype(np.int64)
    ptr = np.sort(rng.integers(0, nvals, 255))
    ptr = np.concatenate([[0], ptr, [nvals]]).astype(np.int64)
    t_np = _best_of(phase_worst_numpy, vals, ptr)
    t_jx = _best_of(phase_worst_jax, vals, ptr)
    exact = bool((phase_worst_numpy(vals, ptr)
                  == phase_worst_jax(vals, ptr)).all())
    assert exact, "phase_worst backends disagree (must be integer-exact)"
    rows.append({
        "name": f"phase_worst[{nvals}vals]",
        "us_per_call": round(min(t_np, t_jx) * 1e6, 1),
        "derived": {"numpy_us": round(t_np * 1e6, 1),
                    "jax_us": round(t_jx * 1e6, 1),
                    "identical_int_output": exact,
                    # export REPRO_PHASE_WORST_CROSSOVER with this to move
                    # the v2 engine's batched solve onto the JAX kernel
                    "recommended_crossover":
                        (nvals if t_jx < t_np else "inf")},
    })

    # --- batched-engine dispatch shapes -------------------------------
    # Re-measure the numpy↔accelerator crossover at the CSR sizes the
    # lane-batched engine actually dispatches (cross-lane concatenation,
    # see BATCHED_DISPATCH_SHAPES above) instead of the historical
    # per-event probe.  The recorded crossover is whatever this box
    # honestly measures — "inf" on hosts where the reduceat path wins at
    # every real shape, which is the expected outcome on CPU-only builds.
    pw_crossover: float | str = "inf"
    shape_rows = {}
    for tag, nvals, nseg in BATCHED_DISPATCH_SHAPES:
        vals = rng.integers(1, 40, nvals).astype(np.int64)
        ptr = np.sort(rng.integers(0, nvals, nseg - 1))
        ptr = np.concatenate([[0], ptr, [nvals]]).astype(np.int64)
        t_np = _best_of(phase_worst_numpy, vals, ptr)
        t_ac = _best_of(phase_worst_accel, vals, ptr)
        exact = bool((phase_worst_numpy(vals, ptr)
                      == np.asarray(phase_worst_accel(vals, ptr))).all())
        assert exact, f"phase_worst backends disagree at {tag} shape"
        shape_rows[tag] = {"nvals": nvals, "nseg": nseg,
                           "numpy_us": round(t_np * 1e6, 1),
                           "accel_us": round(t_ac * 1e6, 1)}
        if t_ac < t_np and pw_crossover == "inf":
            pw_crossover = nvals
    rows.append({
        "name": "phase_worst[batched_dispatch]",
        "us_per_call": min(r["numpy_us"] for r in shape_rows.values()),
        "derived": {"shapes": shape_rows,
                    "identical_int_output": True,
                    # export REPRO_PHASE_WORST_CROSSOVER with this value to
                    # move run_lanes' rate resolution onto the accelerator
                    "recommended_crossover": pw_crossover},
    })

    crossover = autotune_crossover()
    rows.append({
        "name": "maxmin_crossover[autotune]",
        "us_per_call": 0.0,
        "derived": {"crossover_dense_size":
                    ("inf" if crossover == float("inf") else crossover),
                    # re-measured every recording (not a stale default):
                    # autotune_crossover() probes numpy vs JAX afresh and
                    # returns inf only when numpy wins at every probe size
                    "measured_on_this_host": True},
    })
    return rows


if __name__ == "__main__":
    import argparse

    from .common import emit
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    emit(run(fast=not args.full))
