"""Table 4 / Fig. 10 — testbed-style 100-job workload on the 32-GPU fabric:
Avg.JRT / Avg.JWT for ECMP, rECMP (+50% links), SR, vClos."""

from __future__ import annotations

import dataclasses

from repro.core import TESTBED32, simulate, testbed_dataset
from repro.core.topology import ClusterSpec

from .common import timed

RECMP32 = dataclasses.replace(TESTBED32, num_spines=12, uplink_factor=1.5)


def run(fast: bool = True):
    jobs = testbed_dataset(num_jobs=100, seed=0, mean_interarrival=20.0)
    rows = []
    for name, strat, spec in (
            ("ECMP", "ecmp", TESTBED32),
            ("Redundance", "ecmp", RECMP32),
            ("SR", "sr", TESTBED32),
            ("vClos", "vclos", TESTBED32)):
        def work(s=strat, sp=spec):
            rep = simulate(sp, jobs, s)
            return {"avg_jrt": round(rep.avg_jrt, 2),
                    "avg_jwt": round(rep.avg_jwt, 2)}
        rows.append(timed(f"table4_testbed[{name}]", work))
    return rows


if __name__ == "__main__":
    from .common import emit
    emit(run())
