"""Fig. 6 — two-flow contention throughput drop per model/batch/bandwidth."""

from __future__ import annotations

from repro.core.jobs import BATCHES, Job

from .common import timed


def run(fast: bool = True):
    rows = []
    for model, batches in BATCHES.items():
        for batch in batches:
            for gbps in ((100,) if fast else (25, 50, 100)):
                def work(m=model, b=batch, g=gbps):
                    j = Job(0, m, 8, b, 0.0, 1)
                    t1 = j.iter_time(1.0, link_gbps=g)
                    t2 = j.iter_time(0.5, link_gbps=g)  # two-flow contention
                    return {"throughput_drop": round(1 - t1 / t2, 3)}
                rows.append(timed(
                    f"fig6_sensitivity[{model},bs={batch},{gbps}G]", work))
    return rows


if __name__ == "__main__":
    from .common import emit
    emit(run())
