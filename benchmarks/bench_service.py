"""Scheduler-service load test: sustained qps + placement tail latency.

Boots the real asyncio TCP daemon (``repro.service``) on an ephemeral
port, pre-loads a 512-GPU cluster with running jobs, then fans out
thousands of concurrent protocol queries over dozens of connections — a
mixed op stream of ``place`` (bounded-latency placement probe), ``stats``,
``admit``, and ``whatif`` (digital-twin forks, exercising the
fabric-version memo under load).  Client-observed round-trip latency is
recorded per ``place`` call; the derived row carries sustained qps and the
p50/p99 against the gated bound (``scripts/bench_gate.py``).

The row also re-runs the differential replay oracle inline — a trace fed
through the service event loop must stay bit-identical to offline
``simulate()`` for ecmp, sr, and vclos — so ``replay_identical`` lands in
``BENCH_campaign.json`` next to the latency numbers it certifies.

  PYTHONPATH=src python -m benchmarks.bench_service [--full]
"""

from __future__ import annotations

import asyncio
import copy
import time

from .common import timed

#: gated client-observed placement p99 bound (ms) — generous against CI
#: noise, but catches an accidental O(cluster) regression on the hot path
P99_BOUND_MS = 250.0

CLUSTER_GPUS = 512


def _fresh(jobs):
    out = [copy.copy(j) for j in jobs]
    for j in out:
        j.start_time = j.finish_time = j.remaining_iters = None
    return out


def _replay_oracle() -> bool:
    """ecmp + sr + vclos must replay bit-identically through the service
    loop (vclos covers the isolated-strategy requirement)."""
    from repro.core import CLUSTER512, SimConfig, WorkloadSpec, generate_trace
    from repro.service import LiveCluster, RecordingSimulator, replay_trace
    jobs = generate_trace(WorkloadSpec(num_jobs=80, mean_interarrival=60.0,
                                       seed=3))
    for strategy in ("ecmp", "sr", "vclos"):
        cfg = SimConfig(strategy=strategy, scheduler="fifo", seed=0,
                        engine="v2")
        live = LiveCluster(CLUSTER512, cfg)
        rep = replay_trace(live, _fresh(jobs))
        off = RecordingSimulator(CLUSTER512, config=cfg)
        rep_off = off.run(_fresh(jobs))
        if rep.to_journal() != rep_off.to_journal() \
                or live.sim.placements != off.placements:
            return False
    return True


async def _connection(host, port, ops, place_lat):
    from repro.service import AsyncSchedClient
    c = await AsyncSchedClient.connect(host, port)
    try:
        for kind, payload in ops:
            if kind == "place":
                t0 = time.perf_counter()
                await c.place(*payload)
                place_lat.append(time.perf_counter() - t0)
            elif kind == "stats":
                await c.stats()
            elif kind == "admit":
                await c.admit(*payload)
            else:  # whatif
                await c.whatif(*payload[0], strategies=payload[1])
    finally:
        await c.close()


def _op_stream(conn_id: int, n_ops: int):
    """Deterministic mixed op stream — mostly placement probes, a sprinkle
    of twin queries (distinct shapes per connection so the memo sees both
    cold misses and hits)."""
    sizes = (4, 8, 16, 32)
    models = ("resnet50", "bert", "moe", "vgg16")
    ops = []
    for i in range(n_ops):
        r = (conn_id * 7919 + i * 104729) % 100
        if r < 70:
            ops.append(("place", (models[i % 4], sizes[(conn_id + i) % 4],
                                  1000)))
        elif r < 85:
            ops.append(("stats", None))
        elif r < 95:
            ops.append(("admit", ("default", sizes[i % 4])))
        else:
            ops.append(("whatif", ((models[conn_id % 4],
                                    sizes[conn_id % 4], 1000),
                                   ["sr", "ecmp"])))
    return ops


async def _drive(host, port, connections, ops_per_conn):
    place_lat = []
    await asyncio.gather(*[
        _connection(host, port, _op_stream(cid, ops_per_conn), place_lat)
        for cid in range(connections)])
    return place_lat


def run(fast: bool = True):
    from repro.core import (CLUSTER512, SimConfig, WorkloadSpec,
                            generate_trace)
    from repro.service import LiveCluster, SchedulerService, ServerThread

    connections = 64 if fast else 128
    ops_per_conn = 32 if fast else 64
    n_queries = connections * ops_per_conn        # >= 1000 even in fast

    # pre-load: a half-occupied 512-GPU cluster with a real queue
    live = LiveCluster(CLUSTER512,
                       SimConfig(strategy="sr", scheduler="fifo", seed=0,
                                 engine="v2"))
    for job in _fresh(generate_trace(WorkloadSpec(
            num_jobs=40, mean_interarrival=5.0, seed=11))):
        live.submit(job)
    server = ServerThread(SchedulerService(live))
    host, port = server.start()

    state = {}

    def load():
        t0 = time.perf_counter()
        place_lat = asyncio.run(_drive(host, port, connections,
                                       ops_per_conn))
        wall = time.perf_counter() - t0
        lat = sorted(place_lat)
        p = lambda q: round(lat[int(q * (len(lat) - 1))] * 1e3, 3)
        state.update(wall=wall, n_place=len(lat),
                     p50=p(0.50), p99=p(0.99))
        return round(n_queries / wall, 1)

    row = timed(f"bench_service[{connections}x{ops_per_conn}]", load)
    qps = row["derived"]

    from repro.service import SchedClient
    with SchedClient(host, port) as c:
        c.shutdown()
    server.join()

    replay_ok = _replay_oracle()
    row["derived"] = {
        "queries": n_queries,
        "connections": connections,
        "cluster_gpus": CLUSTER_GPUS,
        "qps": qps,
        "n_place_calls": state["n_place"],
        "place_p50_ms": state["p50"],
        "place_p99_ms": state["p99"],
        "p99_bound_ms": P99_BOUND_MS,
        "meets_service_p99_bound": state["p99"] <= P99_BOUND_MS,
        "replay_identical": replay_ok,
    }
    return [row]


if __name__ == "__main__":
    import argparse
    from .common import emit
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    emit(run(fast=not args.full))
