"""Insert the roofline + perf-comparison tables into EXPERIMENTS.md.

  PYTHONPATH=src python -m benchmarks.fill_experiments
"""

import json
import os
import re

from .roofline import ARCH_ORDER, SHAPE_ORDER, fmt_row, load_cells

ROOT = os.path.join(os.path.dirname(__file__), "..")
ART = os.path.join(ROOT, "artifacts", "dryrun")

HILLCLIMB = [("deepseek-moe-16b", "train_4k"), ("qwen1.5-32b", "train_4k"),
             ("zamba2-2.7b", "train_4k")]
ALSO = [("mixtral-8x22b", "train_4k"), ("rwkv6-3b", "train_4k")]


def roofline_md() -> str:
    cols = ["arch", "shape", "status", "t_compute_s", "t_memory_s",
            "t_collective_s", "dominant", "roofline_frac", "useful_flops",
            "mem_gib"]
    lines = ["| " + " | ".join(cols) + " |", "|" + "---|" * len(cols)]
    for c in load_cells("pod"):
        row = fmt_row(c)
        lines.append("| " + " | ".join(str(row.get(k, "—")) for k in cols)
                     + " |")
    return "\n".join(lines)


def perf_md() -> str:
    lines = ["| cell | term | paper-faithful baseline | optimized | Δ |",
             "|---|---|---|---|---|"]
    for arch, shape in HILLCLIMB + ALSO:
        bpath = os.path.join(ART, f"{arch}--{shape}--pod-baseline.json")
        apath = os.path.join(ART, f"{arch}--{shape}--pod.json")
        if not (os.path.exists(bpath) and os.path.exists(apath)):
            continue
        b = json.load(open(bpath))
        a = json.load(open(apath))
        if b.get("status") != "ok" or a.get("status") != "ok":
            continue
        br, ar = b["roofline"], a["roofline"]
        for term in ("t_compute", "t_memory", "t_collective",
                     "useful_flops_ratio"):
            bv, av = br[term], ar[term]
            if term == "useful_flops_ratio":
                delta = f"{av/max(bv,1e-12):.1f}×"
                lines.append(f"| {arch}×{shape} | useful_flops | {bv:.3f} | "
                             f"{av:.3f} | {delta} |")
            else:
                delta = f"{bv/max(av,1e-12):.2f}× faster"
                dom = " **(dominant)**" if br["dominant"] == \
                    term.replace("t_", "") else ""
                lines.append(f"| {arch}×{shape} | {term}{dom} | {bv:.3f} s | "
                             f"{av:.3f} s | {delta} |")
    return "\n".join(lines)


def main() -> None:
    path = os.path.join(ROOT, "EXPERIMENTS.md")
    text = open(path).read()
    text = text.replace("<!-- ROOFLINE_TABLE -->",
                        roofline_md(), 1)
    text = text.replace("<!-- PERF_TABLE -->", perf_md(), 1)
    open(path, "w").write(text)
    print("EXPERIMENTS.md tables filled")


if __name__ == "__main__":
    main()
