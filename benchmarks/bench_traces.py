"""Million-job trace replay benchmark: bounded-memory streaming ingestion.

The acceptance bar for the TraceSource layer (docs/traces.md): a ≥1M-job
generated trace must replay through a windowed campaign via the streaming
reader (1) inside a recorded peak-RSS bound — the reader never
materialises the whole trace — and (2) bit-identical to the eager loader
on a shared prefix.  This module:

(1) writes a 1M-row native-schema trace CSV (vectorized generation),
(2) runs a windowed campaign over it (``run_windowed_campaign``,
    ``store="stream"``) in a **subprocess** and reads the child's
    ``ru_maxrss`` — a clean peak-RSS measurement no parent allocations
    can pollute — recording the ``rss_within_bound`` flag, and
(3) checks ``stream_eq_eager``: the streaming reader's first N jobs
    against an eager ``TraceSource.load()`` of the same N-row prefix.

Both flags gate in ``scripts/bench_gate.py`` when present (older
recordings tolerated, like prior cells).

  PYTHONPATH=src python -m benchmarks.bench_traces [--full]
"""

from __future__ import annotations

import itertools
import json
import os
import subprocess
import sys
import tempfile

import numpy as np

from repro.core.jobs import BATCHES, PROFILES

from .common import timed

N_JOBS = 1_000_000
PREFIX_JOBS = 5_000          # shared streaming-vs-eager parity prefix
WINDOW_JOBS = 1_000
STRIDE_JOBS = 100_000        # sample the long trace, don't simulate it all
RSS_BOUND_MB = 512           # streaming must stay under this; eager 1M-job
                             # Job lists measure well above it

_CHILD = r"""
import json, resource, sys
from repro.core import CLUSTER512, CampaignGrid, run_windowed_campaign
from repro.core.traces import TraceSource

path, window, stride, max_windows = (
    sys.argv[1], int(sys.argv[2]), int(sys.argv[3]), int(sys.argv[4]))
res = run_windowed_campaign(
    CLUSTER512, CampaignGrid(strategies=("ecmp",)),
    TraceSource(path, format="csv"), window, stride, max_windows)
row = res.aggregate()[0]
rss_kb = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
print(json.dumps({"windows": len(res.grid.seeds),
                  "n_finished": int(row["n_finished"]),
                  "jct_mean": round(row["jct_mean"], 1),
                  "peak_rss_mb": round(rss_kb / 1024.0, 1)}))
"""


def _write_trace(path: str, n: int) -> int:
    """Vectorized native-schema trace: Poisson arrivals, small GPU sizes
    (the benchmark measures ingestion, not placement pressure)."""
    rng = np.random.default_rng(0)
    arrivals = np.cumsum(rng.exponential(5.0, n))
    gpus = rng.choice([1, 2, 4, 8], n, p=[0.4, 0.3, 0.2, 0.1])
    iters = rng.integers(50, 500, n)
    models = sorted(PROFILES)
    batches = {m: BATCHES[m][0] for m in models}
    with open(path, "w", newline="") as f:
        f.write("job_id,model,num_gpus,batch_size,arrival,num_iters,"
                "allreduce_algo,deadline\n")
        chunk: list = []
        for i in range(n):
            m = models[i % len(models)]
            chunk.append(f"{i},{m},{gpus[i]},{batches[m]},"
                         f"{arrivals[i]:.6f},{iters[i]},ring,\n")
            if len(chunk) == 100_000:
                f.writelines(chunk)
                chunk.clear()
        f.writelines(chunk)
    return os.path.getsize(path)


def run(fast: bool = True):
    from repro.core.traces import TraceSource

    rows = []
    max_windows = 10 if fast else 20
    tmp = tempfile.mkdtemp(prefix="bench_traces-")
    path = os.path.join(tmp, "trace_1m.csv")

    size = {}
    rows.append(timed(f"bench_traces[generate_{N_JOBS // 1000}k]",
                      lambda: size.setdefault("b", _write_trace(path,
                                                                N_JOBS))))
    rows[-1]["derived"] = {"jobs": N_JOBS,
                           "mb": round(size["b"] / 1e6, 1)}

    # -- (2) windowed campaign over the 1M-job stream, child-process RSS ----
    def windowed():
        r = subprocess.run(
            [sys.executable, "-c", _CHILD, path, str(WINDOW_JOBS),
             str(STRIDE_JOBS), str(max_windows)],
            capture_output=True, text=True,
            env=dict(os.environ, PYTHONPATH=os.pathsep.join(
                filter(None, [os.path.join(os.path.dirname(__file__),
                                           os.pardir, "src"),
                              os.environ.get("PYTHONPATH", "")]))))
        if r.returncode != 0:
            raise RuntimeError(f"windowed replay child failed: "
                               f"{r.stderr[-2000:]}")
        out = json.loads(r.stdout)
        out.update({
            "trace_jobs": N_JOBS, "window_jobs": WINDOW_JOBS,
            "stride_jobs": STRIDE_JOBS, "store": "stream",
            "rss_bound_mb": RSS_BOUND_MB,
            "rss_within_bound": out["peak_rss_mb"] <= RSS_BOUND_MB,
        })
        return out
    rows.append(timed("bench_traces[stream_1m_windowed]", windowed))

    # -- (3) streaming ≡ eager on a shared prefix ---------------------------
    def parity():
        prefix = os.path.join(tmp, "prefix.csv")
        with open(path) as f, open(prefix, "w") as g:
            g.writelines(itertools.islice(f, PREFIX_JOBS + 1))
        eager = TraceSource(prefix, format="csv").load()
        stream = list(itertools.islice(
            TraceSource(path, format="csv").iter_jobs(), PREFIX_JOBS))
        return {"prefix_jobs": PREFIX_JOBS,
                "stream_eq_eager": stream == eager}
    rows.append(timed("bench_traces[stream_eq_eager]", parity))

    for p in (path, os.path.join(tmp, "prefix.csv")):
        if os.path.exists(p):
            os.unlink(p)
    os.rmdir(tmp)
    return rows


if __name__ == "__main__":
    import argparse

    from .common import emit
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="double the windowed-replay coverage")
    emit(run(fast=not ap.parse_args().full))
