"""Shared benchmark plumbing: timing, CSV rows, fast/full switches.

Every module exposes ``run(fast=True) -> list[dict]``; rows carry
``name`` (table/figure id), ``us_per_call`` (wall time of the producing
computation) and ``derived`` (the reproduced quantity).  ``--full`` scales
job counts to the paper's 5000-task datasets.
"""

from __future__ import annotations

import json
import time
from typing import Any, Callable, Dict, List


def timed(name: str, fn: Callable[[], Any]) -> Dict[str, Any]:
    t0 = time.time()
    out = fn()
    dt = (time.time() - t0) * 1e6
    return {"name": name, "us_per_call": round(dt, 1), "derived": out}


def emit(rows: List[Dict[str, Any]]) -> None:
    for r in rows:
        derived = r["derived"]
        if not isinstance(derived, str):
            derived = json.dumps(derived, sort_keys=True)
        print(f"{r['name']},{r['us_per_call']},{derived}")


N_JOBS_FAST = 400
N_JOBS_FULL = 5000
