"""Fig. 5 — scaling factor: ECMP vs contention-free, per model × #GPUs."""

from __future__ import annotations

import numpy as np

from repro.core.jobs import Job
from repro.core.routing import ECMPRouting, SourceRouting, contention
from repro.core.topology import TESTBED32

from .common import timed


def _scaling_factor(model: str, n: int, batch: int, routing_kind: str,
                    seed: int = 0) -> float:
    """T_n / (n·T_1) on the paper's 32-V100 testbed fabric; HD allreduce
    (the collision-prone collective — every step is all-cross once the job
    spans both leafs) under the job's own routed flows."""
    spec = TESTBED32
    job = Job(0, model, n, batch, 0.0, 1, allreduce_algo="hd")
    gpus = list(range(n))  # leaf-contiguous placement
    if routing_kind == "ecmp":
        routing = ECMPRouting(spec, seed=seed)
    else:
        routing = SourceRouting(spec)
    worst = 1
    for kind, phase in job.phases(gpus):
        rep = contention(phase, routing)
        worst = max(worst, rep.max_load)
    t1 = job.compute_time()  # single-GPU iter (no comm)
    tn = job.iter_time(1.0 / worst, link_gbps=spec.link_gbps)
    # throughput per GPU relative to single-GPU throughput
    return (t1 / tn)


def run(fast: bool = True):
    rows = []
    models = [("vgg16", 32), ("resnet50", 32), ("bert", 4), ("moe", 8)]
    sizes = [8, 16, 32] if fast else [8, 16, 32, 64, 128]
    for model, batch in models:
        for n in sizes:
            def work(m=model, b=batch, nn=n):
                sf_ecmp = float(np.mean([_scaling_factor(m, nn, b, "ecmp", s)
                                         for s in range(8)]))
                sf_cf = _scaling_factor(m, nn, b, "sr")
                return {"sf_ecmp": round(sf_ecmp, 3),
                        "sf_contention_free": round(sf_cf, 3)}
            rows.append(timed(f"fig5_scaling[{model},n={n}]", work))
    return rows


if __name__ == "__main__":
    from .common import emit
    emit(run())
