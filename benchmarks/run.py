"""Benchmark harness — one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--full] [--only fig2,table5]
                                          [--json [PATH]]

Prints ``name,us_per_call,derived`` CSV (derived = reproduced quantity).
``--json`` additionally writes a machine-readable report (default
``BENCH_campaign.json``) carrying every row plus the campaign/scale engine
summary (paired-median speedup, trace size, engine) so the perf trajectory
is tracked across PRs."""

from __future__ import annotations

import argparse
import json
import sys

from .common import emit

MODULES = [
    ("fig2", "benchmarks.bench_fig2_hash"),
    ("fig5", "benchmarks.bench_fig5_scaling"),
    ("fig6", "benchmarks.bench_fig6_sensitivity"),
    ("table2", "benchmarks.bench_table2_frag"),
    ("table4", "benchmarks.bench_table4_testbed"),
    ("fig12", "benchmarks.bench_fig12_cluster"),
    ("table5", "benchmarks.bench_table5_lambda"),
    ("table6", "benchmarks.bench_table6_sched"),
    ("table7", "benchmarks.bench_table7_dist"),
    ("campaign", "benchmarks.bench_campaign"),
    ("batched", "benchmarks.bench_batched"),
    ("hetero", "benchmarks.bench_hetero"),
    ("scale", "benchmarks.bench_scale"),
    ("fairshare", "benchmarks.bench_fairshare"),
    ("report", "benchmarks.bench_report"),
    ("service", "benchmarks.bench_service"),
    ("traces", "benchmarks.bench_traces"),
    ("roofline", "benchmarks.roofline"),
]

#: rows whose ``derived`` payload is copied into the JSON summary
SUMMARY_PREFIXES = ("campaign_engine", "campaign_churn", "campaign_resume",
                    "scale_engine", "scale_campaign_cell",
                    "campaign_parallel", "report_suite", "bench_batched",
                    "bench_hetero", "bench_service", "bench_traces")


def write_json(path: str, rows, failures: int, full: bool) -> None:
    summary = {r["name"]: r["derived"] for r in rows
               if r["name"].startswith(SUMMARY_PREFIXES)
               and not isinstance(r["derived"], str)}
    payload = json.dumps({"harness": "benchmarks.run",
                          "mode": "full" if full else "fast",
                          "failures": failures,
                          "engine_summary": summary,
                          "rows": rows}, indent=1, sort_keys=True)
    # atomic: a crash mid-write must not leave a torn BENCH_campaign.json
    # for bench_gate to choke on
    from repro.core.runtime import atomic_write_text
    atomic_write_text(path, payload)
    print(f"[bench] json -> {path}", file=sys.stderr)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale datasets (5000 jobs, both clusters)")
    ap.add_argument("--only", default="")
    ap.add_argument("--json", nargs="?", const="BENCH_campaign.json",
                    default=None, metavar="PATH",
                    help="also write a machine-readable report "
                         "(default BENCH_campaign.json)")
    args = ap.parse_args()
    only = {s.strip() for s in args.only.split(",") if s.strip()}
    import importlib
    print("name,us_per_call,derived")
    failures = 0
    all_rows = []
    for key, modname in MODULES:
        if only and key not in only:
            continue
        try:
            mod = importlib.import_module(modname)
            rows = mod.run(fast=not args.full)
            emit(rows)
            all_rows.extend(rows)
        except Exception as e:  # keep the harness running
            failures += 1
            print(f"{key},0,\"ERROR: {type(e).__name__}: {e}\"",
                  file=sys.stdout)
    if args.json:
        write_json(args.json, all_rows, failures, args.full)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
