"""Benchmark harness — one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--full] [--only fig2,table5]

Prints ``name,us_per_call,derived`` CSV (derived = reproduced quantity)."""

from __future__ import annotations

import argparse
import sys

from .common import emit

MODULES = [
    ("fig2", "benchmarks.bench_fig2_hash"),
    ("fig5", "benchmarks.bench_fig5_scaling"),
    ("fig6", "benchmarks.bench_fig6_sensitivity"),
    ("table2", "benchmarks.bench_table2_frag"),
    ("table4", "benchmarks.bench_table4_testbed"),
    ("fig12", "benchmarks.bench_fig12_cluster"),
    ("table5", "benchmarks.bench_table5_lambda"),
    ("table6", "benchmarks.bench_table6_sched"),
    ("table7", "benchmarks.bench_table7_dist"),
    ("campaign", "benchmarks.bench_campaign"),
    ("roofline", "benchmarks.roofline"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale datasets (5000 jobs, both clusters)")
    ap.add_argument("--only", default="")
    args = ap.parse_args()
    only = {s.strip() for s in args.only.split(",") if s.strip()}
    import importlib
    print("name,us_per_call,derived")
    failures = 0
    for key, modname in MODULES:
        if only and key not in only:
            continue
        try:
            mod = importlib.import_module(modname)
            emit(mod.run(fast=not args.full))
        except Exception as e:  # keep the harness running
            failures += 1
            print(f"{key},0,\"ERROR: {type(e).__name__}: {e}\"",
                  file=sys.stdout)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
