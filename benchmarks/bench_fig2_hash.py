"""Fig. 2 — hash-collision flow-contention proportions vs cluster size."""

from __future__ import annotations

from collections import Counter

import numpy as np

from repro.core.routing import ECMPRouting, contention
from repro.core.topology import CLUSTER512, CLUSTER2048, ClusterSpec
from repro.core.traffic import Flow

from .common import timed

SIZES = {
    "64gpu": ClusterSpec(num_leafs=2, num_spines=32, gpus_per_leaf=32,
                         gpus_per_server=8),
    "512gpu": CLUSTER512,
    "2048gpu": CLUSTER2048,
}


def _collision_profile(spec: ClusterSpec, trials: int, seed0: int = 0):
    """Random cross-leaf permutation traffic under ECMP; histogram of the
    worst per-flow link load (1 = no contention ... 6+ = paper's extreme)."""
    hist: Counter = Counter()
    total = 0
    rng = np.random.default_rng(seed0)
    for t in range(trials):
        n = spec.num_gpus
        perm = rng.permutation(n)
        phase = [Flow(i, int(perm[i]), 1.0) for i in range(n)
                 if spec.leaf_of_gpu(i) != spec.leaf_of_gpu(int(perm[i]))]
        rep = contention(phase, ECMPRouting(spec, seed=t))
        for m in rep.per_flow_max:
            hist[min(m, 6)] += 1
            total += 1
    return {f"x{k}": round(v / total, 4) for k, v in sorted(hist.items())}


def run(fast: bool = True):
    trials = 5 if fast else 20
    rows = []
    for name, spec in SIZES.items():
        rows.append(timed(f"fig2_hash_collision[{name}]",
                          lambda s=spec: _collision_profile(s, trials)))
    return rows


if __name__ == "__main__":
    from .common import emit
    emit(run())
