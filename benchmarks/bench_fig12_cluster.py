"""Fig. 12/13 — CLUSTER512 (and CLUSTER2048 in --full) key indicators for
every strategy: Avg.JRT / JWT / JCT / Stability."""

from __future__ import annotations

import dataclasses

from repro.core import (CLUSTER512, CLUSTER512_OCS, CLUSTER2048,
                        CLUSTER2048_OCS, cluster_dataset, simulate)

from .common import N_JOBS_FAST, N_JOBS_FULL, timed

STRATS = ("best", "ocs-vclos", "vclos", "sr", "balanced", "ecmp")


def run(fast: bool = True):
    rows = []
    n_jobs = N_JOBS_FAST if fast else N_JOBS_FULL
    jobs = cluster_dataset(num_jobs=n_jobs, lam=120.0, seed=0)
    for strat in STRATS:
        spec = CLUSTER512_OCS if strat == "ocs-vclos" else CLUSTER512
        def work(s=strat, sp=spec):
            rep = simulate(sp, jobs, s)
            return {k: round(v, 1) for k, v in rep.row().items()}
        rows.append(timed(f"fig12_cluster512[{strat}]", work))
    if not fast:
        jobs2k = cluster_dataset(num_jobs=n_jobs, lam=15.0, seed=0,
                                 max_gpus=512)
        for strat in STRATS:
            spec = CLUSTER2048_OCS if strat == "ocs-vclos" else CLUSTER2048
            def work(s=strat, sp=spec):
                rep = simulate(sp, jobs2k, s)
                return {k: round(v, 1) for k, v in rep.row().items()}
            rows.append(timed(f"fig13_cluster2048[{strat}]", work))
    return rows


if __name__ == "__main__":
    from .common import emit
    emit(run())
