"""Reproduction-report smoke cell: how long the paper-figure suite takes.

Times the renderer-free data path of every registered figure spec at smoke
scale (``repro.core.figures.build_all``) and records whether the committed
``docs/results.md`` gallery still matches the freshly built tables
(``golden_ok`` — the same comparison ``scripts/docs_lint.py`` gates on).
``--full`` runs the paper-scale suite instead (minutes: v2 streaming
engine, the 2048-GPU CDF sweep) and reports per-figure row counts, so the
full pipeline's cost is on record next to the campaign benches.

  PYTHONPATH=src python -m benchmarks.bench_report [--full]
"""

from __future__ import annotations

from .common import timed


def run(fast: bool = True):
    from repro.core.figures import build_all, qualitative_checks

    scale = "smoke" if fast else "paper"
    tables = []

    def suite():
        tables[:] = build_all(scale)
        return {"figures": len(tables),
                "rows_total": sum(len(t.rows) for t in tables)}
    row = timed(f"report_suite[{scale}]", suite)

    derived = dict(row["derived"])
    derived["orderings_ok"] = not qualitative_checks(tables)
    if fast:
        # golden_ok mirrors the docs-lint drift gate: the committed gallery
        # and smoke CSVs match a regenerated run byte-for-byte
        from repro.launch.report import check_results
        derived["golden_ok"] = not check_results(tables)
    row["derived"] = derived
    return [row]


if __name__ == "__main__":
    from .common import emit
    emit(run())
