"""Table 7 — TPUv4-style large-job distribution: vClos ≈ OCS-vClos when
jobs are big/regular (less fragmentation surface)."""

from __future__ import annotations

from repro.core import (CLUSTER512, CLUSTER512_OCS, TPUV4_SIZE_MIX,
                        cluster_dataset, simulate)

from .common import N_JOBS_FAST, N_JOBS_FULL, timed

STRATS = ("ocs-vclos", "vclos", "best", "sr", "ecmp")


def run(fast: bool = True):
    n_jobs = (N_JOBS_FAST if fast else N_JOBS_FULL) // 2
    jobs = cluster_dataset(num_jobs=n_jobs, lam=400.0, seed=0,
                           size_mix=TPUV4_SIZE_MIX)
    rows = []
    for strat in STRATS:
        spec = CLUSTER512_OCS if strat == "ocs-vclos" else CLUSTER512
        def work(s=strat, sp=spec):
            rep = simulate(sp, jobs, s)
            return {"avg_jrt": round(rep.avg_jrt, 1),
                    "avg_jwt": round(rep.avg_jwt, 1),
                    "avg_jct": round(rep.avg_jct, 1)}
        rows.append(timed(f"table7_tpuv4[{strat}]", work))
    return rows


if __name__ == "__main__":
    from .common import emit
    emit(run())
