"""Heterogeneous-fabric overhead benchmark — the speed-aware rate path.

``bench_hetero[rate_resolution]`` — the gated cell: the 144-cell
acceptance grid (best/sr/ecmp × 12 seeds × 4 loads, 400 jobs/cell,
2048 GPUs) through the serial v2 loop on a *degenerate* hetero spec
(per-tier speeds pinned to ``link_gbps``, every server scale 1.0)
versus the same cells on the plain homogeneous ``CLUSTER2048``.  The
degenerate spec exercises the full speed-aware resolution path
(``spec.is_hetero`` is true) while provably producing the identical
schedule, so the paired ratio isolates the cost of the hetero
arithmetic itself.  Paired-median protocol like ``bench_campaign``:
each repeat times both sides back-to-back and contributes one ratio;
trace generation and job copying are excluded from both sides.
Schedules must be bit-identical (``identical_jct``), and the
acceptance flag ``hetero_ratio_le_1_3x`` requires the median hetero /
homogeneous ratio to stay ≤ 1.3 on this 144-cell grid —
``scripts/bench_gate.py`` enforces both whenever the cell is present
in the recording (docs/heterogeneous.md).

  PYTHONPATH=src python -m benchmarks.bench_hetero [--full]
"""

from __future__ import annotations

import copy
import dataclasses
import time

import numpy as np

from repro.core.simulator import ClusterSimulator
from repro.core.strategies import get_strategy
from repro.core.topology import CLUSTER2048
from repro.core.workloads import WorkloadSpec, generate_trace

#: same 144-cell grid as bench_batched — the established acceptance size
GRID_STRATS = ("best", "sr", "ecmp")
GRID_LOADS = (4.0, 6.0, 8.0, 12.0)
GRID_SEEDS = tuple(range(12))
GRID_JOBS = 400
GRID_MAX_GPUS = 16

#: degenerate hetero twin of CLUSTER2048: every ratio 1.0, every scale
#: 1.0 — is_hetero is true, the schedule is bit-identical by contract
HETERO2048 = dataclasses.replace(
    CLUSTER2048,
    leaf_uplink_gbps=CLUSTER2048.link_gbps,
    server_nic_gbps=CLUSTER2048.link_gbps,
    server_scale=(1.0,) * CLUSTER2048.num_servers)


def _cells():
    out = []
    for s in GRID_STRATS:
        for seed in GRID_SEEDS:
            for load in GRID_LOADS:
                ws = WorkloadSpec(num_jobs=GRID_JOBS, mean_interarrival=load,
                                  max_gpus=GRID_MAX_GPUS, seed=seed)
                out.append((generate_trace(ws), s, seed))
    return out


def _serial_v2(spec, cells):
    reports = []
    for jobs, s, seed in cells:
        sim = ClusterSimulator(spec, strategy=get_strategy(s),
                               seed=seed, engine="v2")
        reports.append(sim.run(jobs))
    return reports


def run(fast: bool = True):
    repeats = 3 if fast else 5
    cells = _cells()

    # warm allocators / strategy caches on a small prefix (excluded)
    _serial_v2(CLUSTER2048, [(copy.deepcopy(j), s, seed)
                             for j, s, seed in cells[:6]])
    _serial_v2(HETERO2048, [(copy.deepcopy(j), s, seed)
                            for j, s, seed in cells[:6]])

    ratios = []
    t_h_best = float("inf")
    rep_homog = rep_hetero = None
    for _ in range(repeats):
        # fresh job copies for both sides, prepared outside the timers
        homog_cells = [(copy.deepcopy(j), s, seed) for j, s, seed in cells]
        hetero_cells = [(copy.deepcopy(j), s, seed) for j, s, seed in cells]
        t0 = time.perf_counter()
        rep_homog = _serial_v2(CLUSTER2048, homog_cells)
        t_homog = time.perf_counter() - t0
        t0 = time.perf_counter()
        rep_hetero = _serial_v2(HETERO2048, hetero_cells)
        t_hetero = time.perf_counter() - t0
        ratios.append(t_hetero / t_homog)
        t_h_best = min(t_h_best, t_hetero)
    ratios.sort()
    med = ratios[len(ratios) // 2]
    identical = all(
        a.n_finished == b.n_finished
        and np.array_equal(np.asarray(a.jcts), np.asarray(b.jcts))
        and np.array_equal(np.asarray(a.jwts), np.asarray(b.jwts))
        for a, b in zip(rep_homog, rep_hetero))
    return [{
        "name": "bench_hetero[rate_resolution]",
        "us_per_call": round(t_h_best * 1e6, 1),
        "derived": {"engine": "v2", "cells": len(cells),
                    "jobs_per_cell": GRID_JOBS, "gpus": 2048,
                    "strategies": list(GRID_STRATS),
                    "repeats": repeats,
                    "hetero_over_homog_ratio": round(med, 3),
                    "ratios_all": [round(r, 3) for r in ratios],
                    "identical_jct": identical,
                    "hetero_ratio_le_1_3x":
                        bool(med <= 1.3 and len(cells) >= 144)},
    }]


if __name__ == "__main__":
    import argparse

    from .common import emit
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="5 paired repeats instead of 3")
    args = ap.parse_args()
    emit(run(fast=not args.full))
