"""Roofline reporter: reads artifacts/dryrun/*.json into the §Roofline table.

Usage:  PYTHONPATH=src python -m benchmarks.roofline [--markdown]
"""

from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")

ARCH_ORDER = ["qwen1.5-32b", "nemotron-4-340b", "tinyllama-1.1b", "olmo-1b",
              "phi-3-vision-4.2b", "whisper-base", "deepseek-moe-16b",
              "mixtral-8x22b", "zamba2-2.7b", "rwkv6-3b"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load_cells(mesh: str = "pod", tag: str = "") -> List[Dict]:
    cells = []
    suffix = f"-{tag}" if tag else ""
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            path = os.path.join(ART, f"{arch}--{shape}--{mesh}{suffix}.json")
            if os.path.exists(path):
                with open(path) as f:
                    cells.append(json.load(f))
    return cells


def fmt_row(c: Dict) -> Dict:
    if c.get("status") == "skipped":
        return {"arch": c["arch"], "shape": c["shape"], "status": "skipped",
                "note": c.get("reason", "")[:60]}
    if c.get("status") != "ok":
        return {"arch": c["arch"], "shape": c["shape"], "status": "ERROR",
                "note": c.get("error", "")[:80]}
    r = c["roofline"]
    dom_t = max(r["t_compute"], r["t_memory"], r["t_collective"])
    frac = r["t_compute"] / dom_t if dom_t else 0.0
    return {
        "arch": c["arch"], "shape": c["shape"], "status": "ok",
        "t_compute_s": f"{r['t_compute']:.3e}",
        "t_memory_s": f"{r['t_memory']:.3e}",
        "t_collective_s": f"{r['t_collective']:.3e}",
        "dominant": r["dominant"],
        "roofline_frac": f"{frac:.2f}",
        "useful_flops": f"{r['useful_flops_ratio']:.2f}",
        "mem_gib": f"{c.get('memory', {}).get('temp_size_in_bytes', 0)/2**30:.1f}",
    }


def run(fast: bool = True):
    from .common import timed
    rows = []
    cells = load_cells("pod")
    ok = sum(1 for c in cells if c.get("status") == "ok")
    skipped = sum(1 for c in cells if c.get("status") == "skipped")
    err = sum(1 for c in cells if c.get("status") not in ("ok", "skipped"))
    rows.append(timed("roofline_summary",
                      lambda: {"cells": len(cells), "ok": ok,
                               "skipped": skipped, "error": err}))
    for c in cells:
        fr = fmt_row(c)
        rows.append({"name": f"roofline[{c['arch']},{c['shape']}]",
                     "us_per_call": 0.0, "derived": fr})
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="pod")
    ap.add_argument("--markdown", action="store_true")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()
    cells = load_cells(args.mesh, args.tag)
    if args.markdown:
        cols = ["arch", "shape", "status", "t_compute_s", "t_memory_s",
                "t_collective_s", "dominant", "roofline_frac",
                "useful_flops", "mem_gib"]
        print("| " + " | ".join(cols) + " |")
        print("|" + "---|" * len(cols))
        for c in cells:
            row = fmt_row(c)
            print("| " + " | ".join(str(row.get(k, "—")) for k in cols) + " |")
    else:
        for c in cells:
            print(json.dumps(fmt_row(c)))


if __name__ == "__main__":
    main()
