"""Lane-batched engine benchmark — the PR 6 acceptance cell.

(1) ``bench_batched[lane_engine]`` — the gated cell: a 144-cell campaign
    grid (best/sr/ecmp × 12 seeds × 4 loads, 400 jobs/cell, 2048 GPUs)
    through one :func:`repro.core.batched.run_lanes` call versus the same
    cells through the serial v2 heap loop.  Paired-median protocol like
    ``bench_campaign``: each repeat times both sides back-to-back and
    contributes one ratio; trace generation and job copying are excluded
    from both sides.  Schedules must be bit-identical
    (``identical_jct``), and the acceptance flag
    ``meets_3x_on_64cell_grid`` requires a ≥3x median speedup on this
    ≥64-cell grid — ``scripts/bench_gate.py`` enforces both whenever the
    cell is present in the recording.
(2) ``bench_batched[report_paper]`` — the ``--scale paper`` report time
    on record: the paper-scale ``jct-vs-load`` campaign figure built with
    ``engine="batched"`` (qualifying cells take the lane engine, the rest
    delegate to v2 — same dispatch the ``--engine batched`` report CLI
    uses).

  PYTHONPATH=src python -m benchmarks.bench_batched [--full]
"""

from __future__ import annotations

import copy
import time

import numpy as np

from repro.core.batched import run_lanes
from repro.core.simulator import ClusterSimulator
from repro.core.strategies import get_strategy
from repro.core.topology import CLUSTER2048
from repro.core.workloads import WorkloadSpec, generate_trace

from .common import timed

#: the gated grid — ≥64 cells per the acceptance criterion; small jobs
#: (max 16 GPUs on 8-GPU servers) keep every lane busy so the lockstep
#: rounds amortise across many events per sweep
GRID_STRATS = ("best", "sr", "ecmp")
GRID_LOADS = (4.0, 6.0, 8.0, 12.0)
GRID_SEEDS = tuple(range(12))
GRID_JOBS = 400
GRID_MAX_GPUS = 16


def _cells():
    out = []
    for s in GRID_STRATS:
        for seed in GRID_SEEDS:
            for load in GRID_LOADS:
                ws = WorkloadSpec(num_jobs=GRID_JOBS, mean_interarrival=load,
                                  max_gpus=GRID_MAX_GPUS, seed=seed)
                out.append((generate_trace(ws), s, seed))
    return out


def _serial_v2(cells):
    reports = []
    for jobs, s, seed in cells:
        sim = ClusterSimulator(CLUSTER2048, strategy=get_strategy(s),
                               seed=seed, engine="v2")
        reports.append(sim.run(jobs))
    return reports


def run(fast: bool = True):
    rows = []
    repeats = 3 if fast else 5
    cells = _cells()

    # warm allocators / strategy caches on a small prefix (excluded)
    run_lanes(CLUSTER2048, [(copy.deepcopy(j), get_strategy(s), seed)
                            for j, s, seed in cells[:6]])
    _serial_v2([(copy.deepcopy(j), s, seed) for j, s, seed in cells[:6]])

    ratios = []
    t_b_best = float("inf")
    rep_v2 = rep_b = None
    for _ in range(repeats):
        # fresh job copies for both sides, prepared outside the timers
        v2_cells = [(copy.deepcopy(j), s, seed) for j, s, seed in cells]
        lanes = [(copy.deepcopy(j), get_strategy(s), seed)
                 for j, s, seed in cells]
        t0 = time.perf_counter()
        rep_v2 = _serial_v2(v2_cells)
        t_v2 = time.perf_counter() - t0
        t0 = time.perf_counter()
        rep_b = run_lanes(CLUSTER2048, lanes)
        t_b = time.perf_counter() - t0
        ratios.append(t_v2 / t_b)
        t_b_best = min(t_b_best, t_b)
    ratios.sort()
    med = ratios[len(ratios) // 2]
    identical = all(
        a.n_finished == b.n_finished
        and np.array_equal(np.asarray(a.jcts), np.asarray(b.jcts))
        and np.array_equal(np.asarray(a.jwts), np.asarray(b.jwts))
        for a, b in zip(rep_v2, rep_b))
    rows.append({
        "name": "bench_batched[lane_engine]",
        "us_per_call": round(t_b_best * 1e6, 1),
        "derived": {"engine": "batched", "cells": len(cells),
                    "jobs_per_cell": GRID_JOBS, "gpus": 2048,
                    "strategies": list(GRID_STRATS),
                    "repeats": repeats,
                    "speedup_vs_serial_v2": round(med, 2),
                    "speedups_all": [round(r, 2) for r in ratios],
                    "identical_jct": identical,
                    "meets_3x_on_64cell_grid":
                        bool(med >= 3.0 and len(cells) >= 64)},
    })

    # -- (2) paper-scale report cell through the batched dispatch ----------
    def report_paper():
        from repro.core.figures import build_all
        (table,) = build_all("paper", names=("jct-vs-load",),
                             engine="batched")
        return {"figure": table.name, "scale": "paper",
                "engine": "batched", "rows": len(table.rows)}
    rows.append(timed("bench_batched[report_paper]", report_paper))
    return rows


if __name__ == "__main__":
    import argparse

    from .common import emit
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="5 paired repeats instead of 3")
    args = ap.parse_args()
    emit(run(fast=not args.full))
